//! `stf` — the Simulator Trace Format: a compact little-endian binary
//! encoding of the job fields the simulator actually consumes, built
//! for the million-job scale path. Reading an stf trace is a straight
//! field decode at fixed offsets — no line splitting, no integer
//! parsing, no record skipping — which is why the bench and serve
//! paths prefer it over SWF/GWF text.
//!
//! ## Layout (all integers little-endian)
//!
//! 32-byte header:
//!
//! | offset | size | field                                   |
//! |--------|------|-----------------------------------------|
//! | 0      | 4    | magic `b"SSTF"`                         |
//! | 4      | 2    | version (currently 1)                   |
//! | 6      | 2    | flags (bit 0: machine fields are valid) |
//! | 8      | 8    | record count                            |
//! | 16     | 4    | machine nodes                           |
//! | 20     | 4    | machine cores per node                  |
//! | 24     | 8    | reserved (zero)                         |
//!
//! then `count` fixed 32-byte records:
//!
//! | offset | size | field       | offset | size | field     |
//! |--------|------|-------------|--------|------|-----------|
//! | 0      | 4    | job id      | 16     | 4    | est. runtime |
//! | 4      | 8    | submit time | 20     | 4    | runtime   |
//! | 12     | 4    | cores       | 24     | 4    | memory MB |
//! |        |      |             | 28     | 2+2  | user, group |
//!
//! ## Contract
//!
//! * **Submit-sorted on write.** [`StfWriter::push`] rejects a record
//!   whose submit time precedes its predecessor's, so every stf file
//!   satisfies the archive-sortedness the streaming job source's
//!   one-record lookahead depends on — checked at conversion time, not
//!   trusted at replay time.
//! * **Converter drops what parsers skip.** `sst-sched convert` writes
//!   only the records the text parsers yield; comments, blanks and
//!   cancelled entries are gone. The reader therefore replays *every*
//!   record, and an stf run is job-for-job identical to the text run
//!   it was converted from (pinned by the cross-format fingerprint
//!   integration test).
//! * **Range-checked encode.** Fields are packed into u32/u16 slots;
//!   encoding errors out (with the job id) rather than truncating when
//!   a value cannot fit. Derived fields (priority, lifecycle state)
//!   are not stored — they are recomputed downstream exactly as they
//!   are for text traces.

use crate::core::time::{SimDuration, SimTime};
use crate::job::Job;
use anyhow::{bail, Context, Result};
use std::io::{Seek, SeekFrom, Write};

/// File magic: the first four bytes of every stf trace.
pub const MAGIC: [u8; 4] = *b"SSTF";
/// Format version this reader/writer speaks.
pub const VERSION: u16 = 1;
/// Header size in bytes.
pub const HEADER_BYTES: usize = 32;
/// Fixed record size in bytes.
pub const RECORD_BYTES: usize = 32;

/// Header flag: the machine fields (nodes, cores per node) are valid.
const FLAG_MACHINE: u16 = 1;
/// Byte offset of the record count within the header (patched by
/// [`StfWriter::finish`]).
const COUNT_OFFSET: u64 = 8;

/// Decoded stf header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StfHeader {
    /// Number of records the body holds.
    pub count: u64,
    /// Target machine recorded at conversion time (`nodes`,
    /// `cores_per_node`); `None` when the producer did not know it.
    pub machine: Option<(usize, u64)>,
}

impl StfHeader {
    /// Encode to the fixed 32-byte on-disk form.
    pub fn encode(&self) -> [u8; HEADER_BYTES] {
        let mut h = [0u8; HEADER_BYTES];
        h[0..4].copy_from_slice(&MAGIC);
        h[4..6].copy_from_slice(&VERSION.to_le_bytes());
        let flags = if self.machine.is_some() { FLAG_MACHINE } else { 0 };
        h[6..8].copy_from_slice(&flags.to_le_bytes());
        h[8..16].copy_from_slice(&self.count.to_le_bytes());
        if let Some((nodes, cores)) = self.machine {
            h[16..20].copy_from_slice(&(nodes as u32).to_le_bytes());
            h[20..24].copy_from_slice(&(cores as u32).to_le_bytes());
        }
        h
    }

    /// Decode and validate a header prefix (magic, version).
    pub fn decode(bytes: &[u8]) -> Result<StfHeader> {
        if bytes.len() < HEADER_BYTES {
            bail!("stf: file too short for a header ({} bytes, need {HEADER_BYTES})", bytes.len());
        }
        if bytes[0..4] != MAGIC {
            bail!("stf: bad magic {:?} (not an stf trace)", &bytes[0..4]);
        }
        let version = u16::from_le_bytes([bytes[4], bytes[5]]);
        if version != VERSION {
            bail!("stf: unsupported version {version} (this reader speaks {VERSION})");
        }
        let flags = u16::from_le_bytes([bytes[6], bytes[7]]);
        let count = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
        let machine = if flags & FLAG_MACHINE != 0 {
            let nodes = u32::from_le_bytes(bytes[16..20].try_into().unwrap()) as usize;
            let cores = u32::from_le_bytes(bytes[20..24].try_into().unwrap()) as u64;
            Some((nodes, cores))
        } else {
            None
        };
        Ok(StfHeader { count, machine })
    }
}

/// Validate a whole in-memory stf image: header plus an exact-length
/// body (`count` promised records, nothing more, nothing less — a
/// truncated download fails here, before any record is decoded).
/// Returns the header; records start at byte [`HEADER_BYTES`].
pub fn validate(bytes: &[u8]) -> Result<StfHeader> {
    let h = StfHeader::decode(bytes)?;
    let want = HEADER_BYTES as u64 + h.count * RECORD_BYTES as u64;
    if bytes.len() as u64 != want {
        bail!(
            "stf: header promises {} records ({} bytes), file has {} bytes (truncated or trailing garbage)",
            h.count,
            want,
            bytes.len()
        );
    }
    Ok(h)
}

fn fit_u32(v: u64, what: &str, id: u64) -> Result<u32> {
    u32::try_from(v)
        .ok()
        .with_context(|| format!("stf: job {id}: {what} {v} exceeds the format's u32 slot"))
}

fn fit_u16(v: u32, what: &str, id: u64) -> Result<u16> {
    u16::try_from(v)
        .ok()
        .with_context(|| format!("stf: job {id}: {what} {v} exceeds the format's u16 slot"))
}

/// Pack a job's trace-carried fields into one fixed record.
pub fn encode_record(job: &Job) -> Result<[u8; RECORD_BYTES]> {
    let mut r = [0u8; RECORD_BYTES];
    r[0..4].copy_from_slice(&fit_u32(job.id, "job id", job.id)?.to_le_bytes());
    r[4..12].copy_from_slice(&job.submit.ticks().to_le_bytes());
    r[12..16].copy_from_slice(&fit_u32(job.cores, "core count", job.id)?.to_le_bytes());
    r[16..20]
        .copy_from_slice(&fit_u32(job.est_runtime.ticks(), "runtime estimate", job.id)?.to_le_bytes());
    r[20..24].copy_from_slice(&fit_u32(job.runtime.ticks(), "runtime", job.id)?.to_le_bytes());
    r[24..28].copy_from_slice(&fit_u32(job.memory_mb, "memory", job.id)?.to_le_bytes());
    r[28..30].copy_from_slice(&fit_u16(job.user, "user id", job.id)?.to_le_bytes());
    r[30..32].copy_from_slice(&fit_u16(job.group, "group id", job.id)?.to_le_bytes());
    Ok(r)
}

/// Unpack one fixed record. Cast-free field decode at fixed offsets:
/// nothing here can fail — image-level validation ([`validate`])
/// already guaranteed the length, and every bit pattern is a legal
/// field value.
pub fn decode_record(rec: &[u8]) -> Job {
    debug_assert_eq!(rec.len(), RECORD_BYTES);
    Job::new(
        u32::from_le_bytes(rec[0..4].try_into().unwrap()) as u64,
        SimTime(u64::from_le_bytes(rec[4..12].try_into().unwrap())),
        u32::from_le_bytes(rec[12..16].try_into().unwrap()) as u64,
        u32::from_le_bytes(rec[24..28].try_into().unwrap()) as u64,
        SimDuration(u32::from_le_bytes(rec[16..20].try_into().unwrap()) as u64),
        SimDuration(u32::from_le_bytes(rec[20..24].try_into().unwrap()) as u64),
        u16::from_le_bytes(rec[28..30].try_into().unwrap()) as u32,
        u16::from_le_bytes(rec[30..32].try_into().unwrap()) as u32,
    )
}

/// Streaming stf writer over any `Write + Seek` sink. Records are
/// written as they arrive (the trace is never buffered); the header's
/// record count starts at zero and is patched by [`StfWriter::finish`],
/// so the converter stays O(1) in memory.
pub struct StfWriter<W: Write + Seek> {
    w: W,
    count: u64,
    last_submit: Option<u64>,
}

impl<W: Write + Seek> StfWriter<W> {
    /// Write the header (count 0 until [`StfWriter::finish`]) and take
    /// ownership of the sink.
    pub fn new(mut w: W, machine: Option<(usize, u64)>) -> Result<StfWriter<W>> {
        let header = StfHeader { count: 0, machine };
        w.write_all(&header.encode()).context("stf: writing header")?;
        Ok(StfWriter { w, count: 0, last_submit: None })
    }

    /// Append one record, enforcing the submit-sorted invariant.
    pub fn push(&mut self, job: &Job) -> Result<()> {
        if let Some(prev) = self.last_submit {
            if job.submit.ticks() < prev {
                bail!(
                    "stf: record {} (job {}) breaks the submit-sorted invariant: submit {} < predecessor's {}",
                    self.count,
                    job.id,
                    job.submit.ticks(),
                    prev
                );
            }
        }
        self.w
            .write_all(&encode_record(job)?)
            .with_context(|| format!("stf: writing record {}", self.count))?;
        self.last_submit = Some(job.submit.ticks());
        self.count += 1;
        Ok(())
    }

    /// Patch the record count into the header, flush, and return the
    /// sink plus the count.
    pub fn finish(mut self) -> Result<(W, u64)> {
        self.w.seek(SeekFrom::Start(COUNT_OFFSET)).context("stf: seeking to patch the record count")?;
        self.w.write_all(&self.count.to_le_bytes()).context("stf: patching the record count")?;
        self.w.flush().context("stf: flushing")?;
        Ok((self.w, self.count))
    }
}

/// Encode a job slice into a complete in-memory stf image (tests,
/// benches, tools). The jobs must already be submit-sorted.
pub fn write_stf(jobs: &[Job], machine: Option<(usize, u64)>) -> Result<Vec<u8>> {
    let mut w = StfWriter::new(std::io::Cursor::new(Vec::new()), machine)?;
    for j in jobs {
        w.push(j)?;
    }
    let (sink, _) = w.finish()?;
    Ok(sink.into_inner())
}

/// What `sst-sched convert` reports.
#[derive(Debug, Clone, Copy)]
pub struct ConvertStats {
    /// Records written (comments/blanks/cancelled entries from a text
    /// input are already gone).
    pub records: u64,
    /// Machine recorded in the output header.
    pub machine: (usize, u64),
    /// Output size in bytes.
    pub bytes: u64,
}

/// Convert any readable trace (`.swf`/`.gwf` text through the fast
/// byte scanner, or `.stf` itself) into an stf file. Streaming: O(1)
/// memory in the trace length on the write side. The output header
/// records the machine the input format implies, so a bare
/// `--trace out.stf` run targets the same platform the text run did.
pub fn convert_trace_file(input: &str, output: &str) -> Result<ConvertStats> {
    let (stream, machine) = crate::trace::stream::open_trace_stream_with_machine(input, true)?;
    let file = std::fs::File::create(output)
        .with_context(|| format!("creating stf output {output:?}"))?;
    let mut w = StfWriter::new(std::io::BufWriter::new(file), Some(machine))?;
    for r in stream {
        let job = r.with_context(|| format!("converting {input:?}"))?;
        w.push(&job)?;
    }
    let (sink, records) = w.finish()?;
    drop(sink);
    let bytes = std::fs::metadata(output).map(|m| m.len()).unwrap_or(0);
    Ok(ConvertStats { records, machine, bytes })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64, submit: u64, cores: u64, mem: u64, est: u64, run: u64) -> Job {
        Job::new(id, SimTime(submit), cores, mem, SimDuration(est), SimDuration(run), 7, 3)
    }

    #[test]
    fn header_roundtrips() {
        for machine in [None, Some((128usize, 16u64))] {
            let h = StfHeader { count: 42, machine };
            let back = StfHeader::decode(&h.encode()).unwrap();
            assert_eq!(back, h);
        }
    }

    #[test]
    fn record_roundtrips_every_field() {
        let j = job(9_001, 123_456_789, 64, 2_048, 3_600, 2_977);
        let back = decode_record(&encode_record(&j).unwrap());
        assert_eq!(back.id, j.id);
        assert_eq!(back.submit, j.submit);
        assert_eq!(back.cores, j.cores);
        assert_eq!(back.memory_mb, j.memory_mb);
        assert_eq!(back.est_runtime, j.est_runtime);
        assert_eq!(back.runtime, j.runtime);
        assert_eq!(back.user, j.user);
        assert_eq!(back.group, j.group);
    }

    #[test]
    fn write_validate_roundtrip() {
        let jobs = vec![job(1, 0, 4, 0, 100, 90), job(2, 50, 8, 512, 200, 200)];
        let bytes = write_stf(&jobs, Some((72, 2))).unwrap();
        assert_eq!(bytes.len(), HEADER_BYTES + 2 * RECORD_BYTES);
        let h = validate(&bytes).unwrap();
        assert_eq!(h.count, 2);
        assert_eq!(h.machine, Some((72, 2)));
        let j = decode_record(&bytes[HEADER_BYTES..HEADER_BYTES + RECORD_BYTES]);
        assert_eq!(j.id, 1);
    }

    #[test]
    fn unsorted_input_rejected_on_write() {
        let jobs = vec![job(1, 100, 1, 0, 10, 10), job(2, 50, 1, 0, 10, 10)];
        let e = write_stf(&jobs, None).unwrap_err().to_string();
        assert!(e.contains("submit-sorted"), "{e}");
        assert!(e.contains("job 2"), "{e}");
    }

    #[test]
    fn out_of_range_fields_rejected_on_write() {
        let mut j = job(1, 0, 1, 0, 10, 10);
        j.cores = u64::from(u32::MAX) + 1;
        let e = encode_record(&j).unwrap_err().to_string();
        assert!(e.contains("core count"), "{e}");
        let mut j = job(1, 0, 1, 0, 10, 10);
        j.user = u32::from(u16::MAX) + 1;
        assert!(encode_record(&j).is_err());
    }

    #[test]
    fn corrupt_images_rejected() {
        let jobs = vec![job(1, 0, 1, 0, 10, 10)];
        let good = write_stf(&jobs, None).unwrap();
        // Truncated body.
        assert!(validate(&good[..good.len() - 1]).unwrap_err().to_string().contains("truncated"));
        // Trailing garbage.
        let mut long = good.clone();
        long.push(0);
        assert!(validate(&long).is_err());
        // Bad magic.
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(validate(&bad).unwrap_err().to_string().contains("magic"));
        // Future version.
        let mut v2 = good.clone();
        v2[4] = 2;
        assert!(validate(&v2).unwrap_err().to_string().contains("version"));
        // Short file.
        assert!(validate(&good[..10]).unwrap_err().to_string().contains("too short"));
    }
}
