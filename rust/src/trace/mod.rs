//! Workload traces: parsers for the two public archive formats the paper
//! uses, a compact binary format for replay at scale, plus statistically
//! calibrated synthetic generators standing in for the actual logs
//! (which are not redistributable with this repo — see DESIGN.md
//! §Substitutions).
//!
//! * [`swf`] — Parallel Workloads Archive "Standard Workload Format"
//!   (SDSC-SP2 log, paper §4.1).
//! * [`gwf`] — Grid Workloads Archive format (GWA-DAS2 trace, §4.1).
//! * [`stf`] — this simulator's binary trace format: a 32-byte header
//!   (magic `SSTF`, version, flags, record count, target machine)
//!   followed by fixed 32-byte little-endian records (id, submit,
//!   cores, runtime estimate, runtime, memory, user, group). Written
//!   submit-sorted by `sst-sched convert`; reading is a cast-free
//!   field decode with no text parsing at all.
//! * [`fast`] — the zero-copy byte scanner: SWAR newline splitting and
//!   branchless ASCII numeric parsing over one loaded buffer, proven
//!   record-for-record identical to the scalar parsers by the
//!   differential suite in `tests/prop_fastparse.rs`.
//! * [`synth`] — DAS-2-like and SDSC-SP2-like generators with the
//!   published marginals (arrival burstiness, power-of-two sizes,
//!   heavy-tailed runtimes, over-estimated user runtimes).
//!
//! If you have the real logs, `sst-sched run --trace path.swf` parses
//! and simulates them directly (add `--fast-parse` for the byte
//! scanner); `sst-sched convert in.swf out.stf` re-encodes any text
//! trace as stf for the cheapest possible replay. All experiments fall
//! back to the generators.

pub mod fast;
pub mod gwf;
pub mod stf;
pub mod stream;
pub mod swf;
pub mod synth;

pub use fast::{ByteRecordSource, FastJobStream, FastTrace};
pub use gwf::parse_gwf;
pub use stream::{
    open_trace_stream_with_machine, stream_trace_file, JobStream, TraceFormat,
};
pub use swf::{parse_swf, write_swf};
pub use synth::{das2::Das2Model, sdsc_sp2::SdscSp2Model};

use crate::job::Job;
use anyhow::Result;

/// A workload: jobs sorted by submit time plus the machine they target.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub jobs: Vec<Job>,
    /// Nodes in the target machine.
    pub nodes: usize,
    /// Cores per node.
    pub cores_per_node: u64,
}

impl Workload {
    pub fn new(name: &str, mut jobs: Vec<Job>, nodes: usize, cores_per_node: u64) -> Workload {
        jobs.sort_by_key(|j| (j.submit, j.id));
        Workload { name: name.to_string(), jobs, nodes, cores_per_node }
    }

    /// A machine-only workload shell (no eager job list): what a
    /// streamed run pairs with
    /// ([`crate::sim::Simulation::with_job_stream`]) — the jobs arrive
    /// through the stream, this only describes the machine.
    pub fn machine(name: &str, nodes: usize, cores_per_node: u64) -> Workload {
        Workload::new(name, Vec::new(), nodes, cores_per_node)
    }

    /// Collect a job stream into an eager workload. The streaming path
    /// feeds the simulator directly and never materializes the trace;
    /// this wrapper keeps every collect-style caller (tools, analysis)
    /// on the same per-line parsers.
    pub fn from_stream(
        name: &str,
        stream: impl Iterator<Item = Result<Job>>,
        nodes: usize,
        cores_per_node: u64,
    ) -> Result<Workload> {
        let jobs = stream.collect::<Result<Vec<Job>>>()?;
        Ok(Workload::new(name, jobs, nodes, cores_per_node))
    }

    pub fn total_cores(&self) -> u64 {
        self.nodes as u64 * self.cores_per_node
    }

    /// Keep only the first `n` jobs (prefix in submit order).
    pub fn truncate(mut self, n: usize) -> Workload {
        self.jobs.truncate(n);
        self
    }

    /// Drop jobs that can never fit the machine (the driver would reject
    /// them; dropping up front keeps validation metrics comparable).
    pub fn drop_infeasible(mut self) -> Workload {
        let cap = self.total_cores();
        self.jobs.retain(|j| j.cores > 0 && j.cores <= cap);
        self
    }

    /// Scale all inter-arrival gaps by `factor` (load scaling: < 1.0
    /// compresses arrivals = higher load).
    pub fn scale_arrivals(mut self, factor: f64) -> Workload {
        if self.jobs.is_empty() {
            return self;
        }
        let base = self.jobs[0].submit.ticks();
        for j in self.jobs.iter_mut() {
            let off = (j.submit.ticks() - base) as f64 * factor;
            j.submit = crate::core::time::SimTime(base + off.round() as u64);
        }
        self
    }

    /// Aggregate demand in core-seconds.
    pub fn core_seconds(&self) -> f64 {
        self.jobs.iter().map(|j| j.core_seconds()).sum()
    }

    /// Offered load: demand / capacity over the submission span.
    pub fn offered_load(&self) -> f64 {
        if self.jobs.len() < 2 {
            return 0.0;
        }
        let span = (self.jobs.last().unwrap().submit - self.jobs[0].submit).as_f64();
        if span == 0.0 {
            return 0.0;
        }
        self.core_seconds() / (span * self.total_cores() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::time::SimTime;

    fn wl(jobs: Vec<Job>) -> Workload {
        Workload::new("t", jobs, 4, 2)
    }

    #[test]
    fn sorts_by_submit() {
        let w = wl(vec![Job::simple(1, 50, 1, 10), Job::simple(2, 10, 1, 10)]);
        assert_eq!(w.jobs[0].id, 2);
    }

    #[test]
    fn drop_infeasible_filters() {
        let w = wl(vec![
            Job::simple(1, 0, 100, 10), // > 8 cores total
            Job::simple(2, 0, 0, 10),   // zero cores
            Job::simple(3, 0, 8, 10),
        ])
        .drop_infeasible();
        assert_eq!(w.jobs.len(), 1);
        assert_eq!(w.jobs[0].id, 3);
    }

    #[test]
    fn scale_arrivals_compresses() {
        let w = wl(vec![Job::simple(1, 100, 1, 1), Job::simple(2, 300, 1, 1)])
            .scale_arrivals(0.5);
        assert_eq!(w.jobs[0].submit, SimTime(100));
        assert_eq!(w.jobs[1].submit, SimTime(200));
    }

    #[test]
    fn offered_load() {
        // 2 jobs x 4 cores x 100s = 800 core-s over 100s span x 8 cores = 1.0
        let w = wl(vec![Job::simple(1, 0, 4, 100), Job::simple(2, 100, 4, 100)]);
        assert!((w.offered_load() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn from_stream_collects_and_sorts() {
        let text = "2 30 -1 60 2 -1 -1 2 100 -1 1 7 1 -1 -1 -1 -1 -1\n\
                    1 0 10 120 4 -1 -1 4 600 -1 1 12 3 -1 -1 -1 -1 -1\n";
        let s = JobStream::new(std::io::Cursor::new(text.as_bytes().to_vec()), TraceFormat::Swf);
        let w = Workload::from_stream("s", s, 4, 2).unwrap();
        assert_eq!(w.jobs.len(), 2);
        assert_eq!(w.jobs[0].id, 1, "from_stream sorts by submit like the eager path");
        let m = Workload::machine("m", 8, 4);
        assert!(m.jobs.is_empty());
        assert_eq!(m.total_cores(), 32);
    }

    #[test]
    fn truncate_takes_prefix() {
        let w = wl((0..10).map(|i| Job::simple(i, i * 10, 1, 1)).collect()).truncate(3);
        assert_eq!(w.jobs.len(), 3);
        assert_eq!(w.jobs[2].id, 2);
    }
}
