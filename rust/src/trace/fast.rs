//! `trace::fast` — the zero-copy byte-level ingestion path.
//!
//! The scalar parsers in [`crate::trace::swf`]/[`crate::trace::gwf`]
//! pay, per record, a `read_line` into a `String`, a Unicode-aware
//! `split_whitespace` (one `Vec<&str>` per line), and per-field
//! `str::parse`. At million-job scale that is the replay bottleneck
//! (the engine itself has been O(1)/event since the ladder-queue PR).
//! This module scans the raw trace bytes instead:
//!
//! * the whole file is read once into a single buffer (the "slice"
//!   half of mmap-or-slice; an `mmap` would drop even that copy but
//!   needs a platform dependency this build intentionally avoids);
//! * records are split with a hand-rolled SWAR memchr — newline search
//!   eight bytes at a time via the exact zero-byte trick from Bit
//!   Twiddling Hacks, no per-line allocation;
//! * ASCII integer fields parse branchlessly (`v = v*10 + d` with a
//!   running validity mask), no UTF-8 validation on the hot path.
//!
//! **Parity is the contract, not speed.** The fast path must yield the
//! byte-identical job sequence and the identical first-error position
//! the scalar parsers produce, which it guarantees three ways:
//!
//! 1. the *semantic* half of parsing (which fields become which jobs,
//!    skip rules, rounding) is the shared `job_from_*_fields`
//!    functions — the paths can only disagree about tokenization;
//! 2. anything outside the fast grammar falls back to the scalar code:
//!    non-ASCII lines re-parse through `parse_*_line` wholesale
//!    (Unicode whitespace semantics), overlong or non-integer numeric
//!    tokens re-parse through `str::parse` (exact overflow and float
//!    rounding semantics, exact error text);
//! 3. the differential property suite in `tests/prop_fastparse.rs`
//!    drives both parsers over adversarial generated bodies and
//!    asserts equality of jobs, order, and error positions.
//!
//! `.stf` traces (see [`crate::trace::stf`]) skip all of the above:
//! their records decode at fixed offsets with no parsing at all. One
//! [`Scanner::step`] function backs the borrowing and owning
//! iterators, so eager == streamed holds by construction here exactly
//! as it does for the scalar [`crate::trace::JobStream`].

use crate::job::Job;
use crate::trace::stf;
use crate::trace::stream::TraceFormat;
use crate::trace::{gwf, swf};
use anyhow::{anyhow, bail, Context, Result};

/// Most fields any parser consumes from one record (SWF group id is
/// field 13). Later fields are counted but never sliced.
const MAX_FIELDS: usize = 13;

/// Find the next `\n` at or after `from`, eight bytes at a time.
///
/// Uses the exact zero-byte test `(v - 0x01…01) & !v & 0x80…80` on
/// `v = word ^ 0x0A…0A`: a high bit survives precisely where a byte of
/// `v` is zero, so `trailing_zeros()/8` is the first newline in the
/// word — no false positives, no per-byte loop until the short tail.
pub(crate) fn memchr_newline(hay: &[u8], from: usize) -> Option<usize> {
    const LO: u64 = 0x0101_0101_0101_0101;
    const HI: u64 = 0x8080_8080_8080_8080;
    const NL: u64 = LO * b'\n' as u64;
    let mut i = from;
    while i + 8 <= hay.len() {
        let w = u64::from_le_bytes(hay[i..i + 8].try_into().unwrap());
        let x = w ^ NL;
        let hit = x.wrapping_sub(LO) & !x & HI;
        if hit != 0 {
            return Some(i + (hit.trailing_zeros() / 8) as usize);
        }
        i += 8;
    }
    hay[i..].iter().position(|&b| b == b'\n').map(|p| i + p)
}

/// ASCII whitespace, byte-for-byte what `char::is_whitespace` accepts
/// in the ASCII range: space, `\t`, `\n`, vertical tab, form feed,
/// `\r`.
#[inline]
fn is_ascii_ws(b: u8) -> bool {
    matches!(b, b' ' | b'\t' | b'\n' | 0x0B | 0x0C | b'\r')
}

/// `str::trim` restricted to ASCII input (the fast path never sees a
/// non-ASCII line — those fall back to the scalar parser).
fn trim_ascii(mut line: &[u8]) -> &[u8] {
    while let Some((&b, rest)) = line.split_first() {
        if !is_ascii_ws(b) {
            break;
        }
        line = rest;
    }
    while let Some((&b, rest)) = line.split_last() {
        if !is_ascii_ws(b) {
            break;
        }
        line = rest;
    }
    line
}

/// Split on ASCII whitespace runs. Fills `out` with the first
/// [`MAX_FIELDS`] field slices and returns the *total* field count
/// (the short-line error reports the exact count).
fn split_fields<'a>(line: &'a [u8], out: &mut [&'a [u8]; MAX_FIELDS]) -> usize {
    let mut count = 0;
    let mut i = 0;
    while i < line.len() {
        while i < line.len() && is_ascii_ws(line[i]) {
            i += 1;
        }
        if i >= line.len() {
            break;
        }
        let start = i;
        while i < line.len() && !is_ascii_ws(line[i]) {
            i += 1;
        }
        if count < MAX_FIELDS {
            out[count] = &line[start..i];
        }
        count += 1;
    }
    count
}

/// Branchless accumulate of 1–18 ASCII digits. 18 digits can never
/// overflow a u64, so the only failure mode is a non-digit byte —
/// tracked with a validity mask instead of a per-byte branch.
#[inline]
fn parse_u64_digits(digits: &[u8]) -> Option<u64> {
    let mut v: u64 = 0;
    let mut ok = !digits.is_empty();
    for &b in digits {
        let d = b.wrapping_sub(b'0');
        ok &= d <= 9;
        v = v.wrapping_mul(10).wrapping_add(u64::from(d));
    }
    if ok {
        Some(v)
    } else {
        None
    }
}

/// Split an optional ASCII sign off a numeric token.
#[inline]
fn split_sign(tok: &[u8]) -> (bool, &[u8]) {
    match tok.first() {
        Some(b'-') => (true, &tok[1..]),
        Some(b'+') => (false, &tok[1..]),
        _ => (false, tok),
    }
}

/// Parse one i64 field. Fast grammar `[+-]?[0-9]{1,18}` (always fits);
/// anything else — overlong digit runs, junk, empty — takes the cold
/// path through `str::parse::<i64>` so overflow semantics and the
/// error text are *exactly* the scalar parser's.
fn parse_i64_tok(tok: &[u8], kind: &str, lineno: usize, field: usize) -> Result<i64> {
    let (neg, digits) = split_sign(tok);
    if (1..=18).contains(&digits.len()) {
        if let Some(v) = parse_u64_digits(digits) {
            let v = v as i64;
            return Ok(if neg { -v } else { v });
        }
    }
    let s = std::str::from_utf8(tok).expect("fast path only tokenizes ASCII lines");
    s.parse::<i64>()
        .with_context(|| format!("{kind} line {lineno}: field {field} = {s:?}"))
}

/// Parse one f64 field. Fast grammar: pure integers of ≤ 15 digits —
/// below 2^53 every one is exactly representable, so `u64 as f64`
/// produces the bit the decimal float parser would. Any fractional,
/// exponent, or overlong token takes `str::parse::<f64>` for exact
/// rounding parity.
fn parse_f64_tok(tok: &[u8], lineno: usize, field: usize) -> Result<f64> {
    let (neg, digits) = split_sign(tok);
    if (1..=15).contains(&digits.len()) {
        if let Some(v) = parse_u64_digits(digits) {
            let v = v as f64;
            return Ok(if neg { -v } else { v });
        }
    }
    let s = std::str::from_utf8(tok).expect("fast path only tokenizes ASCII lines");
    s.parse::<f64>()
        .with_context(|| format!("gwf line {lineno}: field {field} = {s:?}"))
}

/// Fast SWF line body (already ASCII-trimmed, non-comment, non-blank).
fn parse_swf_fast(line: &[u8], lineno: usize) -> Result<Option<Job>> {
    let mut f: [&[u8]; MAX_FIELDS] = [&[]; MAX_FIELDS];
    let n = split_fields(line, &mut f);
    if n < 11 {
        bail!("swf line {}: expected >= 11 fields, got {}", lineno, n);
    }
    let g = |idx: usize| parse_i64_tok(f[idx], "swf", lineno, idx + 1);
    let id = g(0)?;
    let submit = g(1)?;
    let run = g(3)?;
    let used_procs = g(4)?;
    let req_procs = g(7)?;
    let req_time = g(8)?;
    let req_mem = g(9)?;
    let user = if n > 11 { g(11)? } else { -1 };
    let group = if n > 12 { g(12)? } else { -1 };
    Ok(swf::job_from_swf_fields(id, submit, run, used_procs, req_procs, req_time, req_mem, user, group))
}

/// Fast GWF line body (already ASCII-trimmed, non-comment, non-blank).
fn parse_gwf_fast(line: &[u8], lineno: usize) -> Result<Option<Job>> {
    let mut f: [&[u8]; MAX_FIELDS] = [&[]; MAX_FIELDS];
    let n = split_fields(line, &mut f);
    if n < 13 {
        bail!("gwf line {}: expected >= 13 fields, got {}", lineno, n);
    }
    let g = |idx: usize| parse_f64_tok(f[idx], lineno, idx + 1);
    let id = g(0)?;
    let submit = g(1)?;
    let run = g(3)?;
    let nproc = g(4)?;
    let req_n = g(7)?;
    let req_time = g(8)?;
    let req_mem = g(9)?;
    let user = g(11)?;
    let group = g(12)?;
    Ok(gwf::job_from_gwf_fields(id, submit, run, nproc, req_n, req_time, req_mem, user, group))
}

/// Parse one raw text line (no trailing `\n`; a CRLF's `\r` is still
/// attached and trimmed here). Pure-ASCII lines take the byte path;
/// anything with a non-ASCII byte re-parses through the scalar line
/// parser so Unicode whitespace/digit semantics stay authoritative.
pub(crate) fn parse_text_record(raw: &[u8], lineno: usize, format: TraceFormat) -> Result<Option<Job>> {
    if !raw.is_ascii() {
        let s = std::str::from_utf8(raw)
            .map_err(|_| anyhow!("trace line {lineno}: invalid UTF-8"))?;
        return match format {
            TraceFormat::Swf => swf::parse_swf_line(s, lineno),
            TraceFormat::Gwf => gwf::parse_gwf_line(s, lineno),
            TraceFormat::Stf => bail!("stf is binary; it has no text lines"),
        };
    }
    let line = trim_ascii(raw);
    if line.is_empty() {
        return Ok(None);
    }
    match format {
        TraceFormat::Swf if line[0] == b';' => Ok(None),
        TraceFormat::Swf => parse_swf_fast(line, lineno),
        TraceFormat::Gwf if line[0] == b'#' => Ok(None),
        TraceFormat::Gwf => parse_gwf_fast(line, lineno),
        TraceFormat::Stf => bail!("stf is binary; it has no text lines"),
    }
}

/// Cursor over a trace body. One `step` function drives both the
/// borrowing [`ByteRecordSource`] and the owning [`FastJobStream`], so
/// the two cannot disagree.
pub(crate) struct Scanner {
    pos: usize,
    lineno: usize,
}

impl Scanner {
    pub(crate) fn new(body_start: usize) -> Scanner {
        Scanner { pos: body_start, lineno: 0 }
    }

    /// Yield the next job, a first error (text formats: wrapped with
    /// the 1-based line number and the line's byte offset, the same
    /// envelope the scalar [`crate::trace::JobStream`] applies), or
    /// `None` at end of input. stf records cannot fail here — image
    /// validation at open time already checked length, and every bit
    /// pattern is a legal field value.
    pub(crate) fn step(&mut self, bytes: &[u8], format: TraceFormat) -> Option<Result<Job>> {
        if format == TraceFormat::Stf {
            if self.pos >= bytes.len() {
                return None;
            }
            let rec = &bytes[self.pos..self.pos + stf::RECORD_BYTES];
            self.pos += stf::RECORD_BYTES;
            return Some(Ok(stf::decode_record(rec)));
        }
        while self.pos < bytes.len() {
            let start = self.pos;
            let end = memchr_newline(bytes, start).unwrap_or(bytes.len());
            self.pos = end + 1;
            self.lineno += 1;
            match parse_text_record(&bytes[start..end], self.lineno, format) {
                Ok(None) => {}
                Ok(Some(job)) => return Some(Ok(job)),
                Err(e) => {
                    return Some(Err(e.context(format!(
                        "trace line {} at byte offset {}",
                        self.lineno, start
                    ))));
                }
            }
        }
        None
    }
}

/// A trace loaded as one byte buffer, ready for zero-copy scanning.
pub struct FastTrace {
    name: String,
    format: TraceFormat,
    bytes: Vec<u8>,
    machine: (usize, u64),
}

impl FastTrace {
    /// Read `path` into memory, detecting the format from the
    /// extension. `.stf` images are validated up front (magic, version,
    /// exact length) and carry their machine in the header; text
    /// formats use the format's default machine.
    pub fn open(path: &str) -> Result<FastTrace> {
        FastTrace::open_as(path, TraceFormat::from_path(path))
    }

    /// Like [`FastTrace::open`] but with the format declared by the
    /// caller (a config's `workload.kind` wins over the extension).
    pub fn open_as(path: &str, format: TraceFormat) -> Result<FastTrace> {
        let bytes =
            std::fs::read(path).with_context(|| format!("reading trace file {path:?}"))?;
        FastTrace::from_bytes(path, format, bytes)
    }

    /// Wrap an in-memory trace image (tests, benches).
    pub fn from_bytes(name: &str, format: TraceFormat, bytes: Vec<u8>) -> Result<FastTrace> {
        let machine = if format == TraceFormat::Stf {
            let header = stf::validate(&bytes).with_context(|| format!("validating {name:?}"))?;
            header.machine.unwrap_or_else(|| format.default_machine())
        } else {
            format.default_machine()
        };
        Ok(FastTrace { name: name.to_string(), format, bytes, machine })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn format(&self) -> TraceFormat {
        self.format
    }

    /// `(nodes, cores_per_node)` this trace targets.
    pub fn machine(&self) -> (usize, u64) {
        self.machine
    }

    /// Trace image size (observability; bench reporting).
    pub fn len_bytes(&self) -> usize {
        self.bytes.len()
    }

    fn body_start(&self) -> usize {
        if self.format == TraceFormat::Stf {
            stf::HEADER_BYTES
        } else {
            0
        }
    }

    /// Borrowing record iterator over the loaded bytes.
    pub fn records(&self) -> ByteRecordSource<'_> {
        ByteRecordSource {
            bytes: &self.bytes,
            format: self.format,
            scanner: Scanner::new(self.body_start()),
            yielded: 0,
            done: false,
        }
    }

    /// Eager parse: collect every record (first error aborts) — the
    /// fast twin of `parse_swf`/`parse_gwf`/an stf body decode.
    pub fn parse(&self) -> Result<Vec<Job>> {
        self.records().collect()
    }

    /// Convert into an owning stream for
    /// [`crate::sim::Simulation::with_job_stream`] (which needs
    /// `'static + Send`).
    pub fn into_stream(self) -> FastJobStream {
        let body_start = self.body_start();
        FastJobStream {
            bytes: self.bytes,
            format: self.format,
            scanner: Scanner::new(body_start),
            yielded: 0,
            done: false,
        }
    }
}

/// Borrowing iterator over a [`FastTrace`]'s records: yields `Ok(job)`
/// per valid record, skips comments/blanks/cancelled records silently,
/// and yields one `Err` (then ends) on the first broken line — the
/// same contract as the scalar [`crate::trace::JobStream`].
pub struct ByteRecordSource<'a> {
    bytes: &'a [u8],
    format: TraceFormat,
    scanner: Scanner,
    yielded: u64,
    done: bool,
}

impl ByteRecordSource<'_> {
    /// Records yielded so far (observability parity with
    /// [`crate::trace::JobStream::yielded`]).
    pub fn yielded(&self) -> u64 {
        self.yielded
    }
}

impl Iterator for ByteRecordSource<'_> {
    type Item = Result<Job>;

    fn next(&mut self) -> Option<Result<Job>> {
        if self.done {
            return None;
        }
        match self.scanner.step(self.bytes, self.format) {
            Some(Ok(job)) => {
                self.yielded += 1;
                Some(Ok(job))
            }
            Some(Err(e)) => {
                self.done = true;
                Some(Err(e))
            }
            None => {
                self.done = true;
                None
            }
        }
    }
}

/// Owning variant of [`ByteRecordSource`] — same `Scanner`, same
/// record-for-record behavior, but `'static + Send` so it can feed
/// [`crate::sim::Simulation::with_job_stream`].
pub struct FastJobStream {
    bytes: Vec<u8>,
    format: TraceFormat,
    scanner: Scanner,
    yielded: u64,
    done: bool,
}

impl FastJobStream {
    /// Records yielded so far.
    pub fn yielded(&self) -> u64 {
        self.yielded
    }
}

impl Iterator for FastJobStream {
    type Item = Result<Job>;

    fn next(&mut self) -> Option<Result<Job>> {
        if self.done {
            return None;
        }
        match self.scanner.step(&self.bytes, self.format) {
            Some(Ok(job)) => {
                self.yielded += 1;
                Some(Ok(job))
            }
            Some(Err(e)) => {
                self.done = true;
                Some(Err(e))
            }
            None => {
                self.done = true;
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::time::{SimDuration, SimTime};

    #[test]
    fn memchr_matches_naive_search() {
        let mut hay = Vec::new();
        for i in 0..200u8 {
            hay.push(if i % 7 == 0 { b'\n' } else { b'a' + (i % 23) });
        }
        hay.extend_from_slice(b"tail without newline");
        let mut from = 0;
        loop {
            let naive = hay[from..].iter().position(|&b| b == b'\n').map(|p| from + p);
            assert_eq!(memchr_newline(&hay, from), naive, "from={from}");
            match naive {
                Some(p) => from = p + 1,
                None => break,
            }
        }
        assert_eq!(memchr_newline(b"", 0), None);
        assert_eq!(memchr_newline(b"\n", 0), Some(0));
        assert_eq!(memchr_newline(b"abcdefg\n", 0), Some(7));
    }

    #[test]
    fn int_parse_matches_std() {
        for s in ["0", "-1", "42", "+7", "123456789012345678", "999999999999999999"] {
            let fast = parse_i64_tok(s.as_bytes(), "swf", 1, 1).unwrap();
            assert_eq!(fast, s.parse::<i64>().unwrap(), "{s}");
        }
        // Cold path: overlong but valid (19 digits), and junk.
        let big = "9223372036854775807"; // i64::MAX, 19 digits
        assert_eq!(parse_i64_tok(big.as_bytes(), "swf", 1, 1).unwrap(), i64::MAX);
        for bad in ["", "-", "+", "x", "1x", "12345678901234567890123"] {
            assert!(parse_i64_tok(bad.as_bytes(), "swf", 1, 1).is_err(), "{bad:?}");
            assert!(bad.parse::<i64>().is_err(), "{bad:?}");
        }
    }

    #[test]
    fn float_parse_matches_std() {
        for s in ["0", "-1", "33", "61.5", "1e3", "999999999999999", "900.0", "-0.5"] {
            let fast = parse_f64_tok(s.as_bytes(), 1, 1).unwrap();
            let std = s.parse::<f64>().unwrap();
            assert_eq!(fast.to_bits(), std.to_bits(), "{s}");
        }
        assert!(parse_f64_tok(b"nope", 1, 1).is_err());
    }

    #[test]
    fn swf_fast_matches_scalar_on_sample() {
        let text = "\
; header\r\n1 0 10 120 4 -1 -1 4 600 -1 1 12 3 -1 -1 -1 -1 -1\n\
2 30 -1 60 -1 -1 -1 8 100 2048 1 7 1 -1 -1 -1 -1 -1\r\n\
3 60 5 -1 4 -1 -1 4 600 -1 0 2 1 -1 -1 -1 -1 -1";
        let trace =
            FastTrace::from_bytes("t.swf", TraceFormat::Swf, text.as_bytes().to_vec()).unwrap();
        let fast = trace.parse().unwrap();
        let scalar = crate::trace::parse_swf(text).unwrap();
        assert_eq!(fast.len(), scalar.len());
        for (a, b) in fast.iter().zip(&scalar) {
            assert_eq!((a.id, a.submit, a.cores, a.runtime), (b.id, b.submit, b.cores, b.runtime));
        }
    }

    #[test]
    fn error_positions_match_scalar() {
        let text = "1 0 10 120 4 -1 -1 4 600 -1 1 12 3 -1 -1 -1 -1 -1\n1 2 3\n";
        let trace =
            FastTrace::from_bytes("t.swf", TraceFormat::Swf, text.as_bytes().to_vec()).unwrap();
        let fast_err = trace.parse().unwrap_err().to_string();
        let scalar_err = crate::trace::parse_swf(text).unwrap_err().to_string();
        assert!(fast_err.contains(&scalar_err), "{fast_err} vs {scalar_err}");
        assert!(fast_err.contains("trace line 2 at byte offset 50"), "{fast_err}");
    }

    #[test]
    fn stream_iterator_ends_after_error() {
        let text = "bad line here\n";
        let trace =
            FastTrace::from_bytes("t.swf", TraceFormat::Swf, text.as_bytes().to_vec()).unwrap();
        let mut s = trace.into_stream();
        assert!(s.next().unwrap().is_err());
        assert!(s.next().is_none());
    }

    #[test]
    fn stf_bytes_scan_back_to_jobs() {
        let jobs = vec![
            Job::new(1, SimTime(0), 4, 0, SimDuration(100), SimDuration(90), 1, 1),
            Job::new(2, SimTime(50), 8, 512, SimDuration(200), SimDuration(200), 2, 1),
        ];
        let bytes = stf::write_stf(&jobs, Some((128, 1))).unwrap();
        let trace = FastTrace::from_bytes("t.stf", TraceFormat::Stf, bytes).unwrap();
        assert_eq!(trace.machine(), (128, 1));
        let back = trace.parse().unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].id, 1);
        assert_eq!(back[1].submit, SimTime(50));
    }
}
