//! The availability timeline — the planning core every forward-looking
//! scheduling decision reads from (tentpole of the unified planning
//! refactor), generalized to multi-resource demands.
//!
//! [`AvailabilityProfile`] is an incremental, time-indexed free-resource
//! step function: one breakpoint list `(time, free)` per *active
//! dimension*, where `free` holds until the next breakpoint and the last
//! segment extends to infinity. The cores dimension always exists; the
//! memory dimension is **lazily materialized** — it is allocated the
//! first time a memory-carrying hold or rebuild touches the profile, so
//! cores-only workloads pay zero extra cost (pinned by the
//! `engine_throughput` bench). Both dimensions share the same signed
//! breakpoint algebra ([`Timeline`], private).
//!
//! The profile is owned by the simulation core (`sim::SchedulerComponent`),
//! which updates it *incrementally* on job start/finish, reservation
//! claim/release and node failure/repair instead of rebuilding it from
//! sorted release vectors every scheduling round. Policies receive it
//! read-only through `sched::SchedInput::profile`:
//!
//! * every blocking discipline (FCFS/SJF/LJF/BestFit head admission)
//!   routes through [`AvailabilityProfile::can_place_v`], which is what
//!   makes a blocked head refuse to start into a *future* advance
//!   reservation or outage window;
//! * EASY backfilling derives its shadow time and extra cores from
//!   [`AvailabilityProfile::earliest_slot_v`] and admission-checks
//!   candidates with `can_place_v`;
//! * conservative backfilling clones the profile into a per-round
//!   scratch plan and lays every queued job's reservation onto it with
//!   [`AvailabilityProfile::hold_v`].
//!
//! `free` is stored *signed*: planning holds (e.g. an advance
//! reservation over a window where jobs are still draining) may
//! transiently over-commit a window. Readers clamp to zero — an
//! over-committed window simply offers no capacity — while the signed
//! algebra keeps every `hold`/`release` pair an exact inverse, the
//! invariant the incremental maintenance relies on
//! (property-tested in rust/tests/prop_profile.rs).
//!
//! The profile is a *planning estimate*, trusted the way backfilling
//! trusts user runtime estimates: a job that overruns its estimate
//! appears free in the profile before its resources actually return
//! (exactly as the per-round rebuild it replaces behaved). Admission is
//! therefore always re-checked against the exact [`super::Cluster`]
//! accounting; the profile only decides what is *worth* checking.

use super::vector::ResourceVector;

/// One dimension of the availability timeline: the breakpoint list and
/// its signed algebra. Cores and memory are both instances of this.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Timeline {
    /// `(time, free)` breakpoints; times strictly increasing, adjacent
    /// `free` values distinct (canonical form), last segment open-ended.
    points: Vec<(u64, i64)>,
}

impl Timeline {
    const EMPTY: Timeline = Timeline { points: Vec::new() };

    fn new(now: u64, free: i64) -> Timeline {
        Timeline { points: vec![(now, free)] }
    }

    /// Rebuild from scratch: `base` at `now` plus signed deltas at
    /// future instants. Deltas at or before `now` merge into the base
    /// value, mirroring the per-round rebuild this structure replaces.
    fn rebuild(&mut self, now: u64, base: i64, mut deltas: Vec<(u64, i64)>) {
        deltas.retain(|d| d.1 != 0);
        deltas.sort_unstable();
        self.points.clear();
        self.points.push((now, base));
        for (t, d) in deltas {
            let t = t.max(now);
            let last = *self.points.last().unwrap();
            if t == last.0 {
                self.points.last_mut().unwrap().1 = last.1 + d;
            } else {
                self.points.push((t, last.1 + d));
            }
        }
        self.points.dedup_by(|a, b| a.1 == b.1);
    }

    /// Drop history before `now`: breakpoints at or before `now` merge
    /// into the head segment. O(k) in the breakpoints trimmed.
    fn advance(&mut self, now: u64) {
        let i = self.seg_at(now);
        if i > 0 {
            self.points.drain(..i);
        }
        if let Some(p) = self.points.first_mut() {
            if p.0 < now {
                p.0 = now;
            }
        }
    }

    /// Index of the segment containing `t` (the last breakpoint at or
    /// before `t`); the first segment when `t` precedes the timeline.
    fn seg_at(&self, t: u64) -> usize {
        match self.points.binary_search_by_key(&t, |p| p.0) {
            Ok(i) => i,
            Err(0) => 0,
            Err(i) => i - 1,
        }
    }

    /// Insert a breakpoint at `t` (no-op if present or out of range).
    fn split_at(&mut self, t: u64) {
        if t == u64::MAX {
            return;
        }
        match self.points.binary_search_by_key(&t, |p| p.0) {
            Ok(_) => {}
            Err(0) => {} // before the timeline origin; `apply` clips instead
            Err(i) => {
                let f = self.points[i - 1].1;
                self.points.insert(i, (t, f));
            }
        }
    }

    /// Add `delta` to every instant in `[from, until)`, keeping the
    /// breakpoint list canonical. Interior points shift together, so
    /// only the two window boundaries can need coalescing — the whole
    /// operation touches O(log n + window) points, never the full list.
    fn apply(&mut self, from: u64, until: u64, delta: i64) {
        if delta == 0 || self.points.is_empty() {
            return;
        }
        let from = from.max(self.points[0].0);
        if from >= until {
            return;
        }
        self.split_at(from);
        self.split_at(until);
        let a = match self.points.binary_search_by_key(&from, |p| p.0) {
            Ok(i) => i,
            Err(_) => unreachable!("split_at(from) must leave a breakpoint at from"),
        };
        let mut b = a;
        while b < self.points.len() && self.points[b].0 < until {
            self.points[b].1 += delta;
            b += 1;
        }
        // Coalesce the `until` boundary first (does not shift `a`),
        // then the `from` boundary.
        if b < self.points.len() && self.points[b].1 == self.points[b - 1].1 {
            self.points.remove(b);
        }
        if a > 0 && self.points[a].1 == self.points[a - 1].1 {
            self.points.remove(a);
        }
    }

    /// Free amount at instant `t`, clamped at zero.
    fn free_at(&self, t: u64) -> u64 {
        if self.points.is_empty() {
            return 0;
        }
        self.points[self.seg_at(t)].1.max(0) as u64
    }

    /// Whether `amount` is free throughout `[from, from + duration)`.
    /// The pre-origin part of the window, if any, is the past and is
    /// ignored — only the portion the timeline covers is checked.
    fn can_place(&self, from: u64, duration: u64, amount: u64) -> bool {
        if duration == 0 {
            return true;
        }
        if self.points.is_empty() {
            return false;
        }
        let end = from.saturating_add(duration);
        let from = from.max(self.points[0].0);
        if from >= end {
            return true; // window entirely before the origin
        }
        let c = amount as i64;
        let mut i = self.seg_at(from);
        loop {
            if self.points[i].1 < c {
                return false;
            }
            let seg_end = self.points.get(i + 1).map(|p| p.0).unwrap_or(u64::MAX);
            if seg_end >= end {
                return true;
            }
            i += 1;
        }
    }

    /// Earliest time >= `from` at which `amount` is free continuously
    /// for `duration`. Binary-searches to the starting segment and scans
    /// forward — O(log n + k). `None` only when the request exceeds the
    /// timeline's eventual capacity (infeasible).
    fn earliest_slot(&self, from: u64, amount: u64, duration: u64) -> Option<u64> {
        if self.points.is_empty() {
            return None;
        }
        let c = amount as i64;
        let duration = duration.max(1);
        let mut candidate = from.max(self.points[0].0);
        let mut i = self.seg_at(candidate);
        loop {
            let free = self.points[i].1;
            let seg_end = self.points.get(i + 1).map(|p| p.0).unwrap_or(u64::MAX);
            if free < c {
                if seg_end == u64::MAX {
                    return None; // blocked forever: infeasible request
                }
                candidate = seg_end;
            } else if seg_end == u64::MAX || seg_end >= candidate.saturating_add(duration) {
                return Some(candidate);
            }
            i += 1;
            debug_assert!(i < self.points.len(), "open-ended tail must terminate the scan");
        }
    }

    /// Whether free never decreases over the timeline (no capacity
    /// windows ahead: pure release streams).
    fn is_monotone(&self) -> bool {
        self.points.windows(2).all(|w| w[0].1 <= w[1].1)
    }

    /// Structural invariants: strictly increasing times, canonical (no
    /// adjacent equal frees), free never above `cap`.
    fn check(&self, cap: u64) -> bool {
        !self.points.is_empty()
            && self.points.windows(2).all(|w| w[0].0 < w[1].0 && w[0].1 != w[1].1)
            && self.points.iter().all(|p| p.1 <= cap as i64)
    }
}

/// Incremental future free-resource timeline (cores always; memory as a
/// lazily materialized second dimension).
///
/// Complexity: `earliest_slot`/`can_place` are O(log n + k) in the
/// number of breakpoints per active dimension (k = segments actually
/// inspected); the mutators are O(n) worst case for the breakpoint
/// insert but touch only the affected span — there is no per-round sort
/// or rebuild.
#[derive(Debug, Clone)]
pub struct AvailabilityProfile {
    cores: Timeline,
    /// The memory dimension; `None` until the first memory-carrying
    /// operation (lazy materialization — cores-only workloads never
    /// allocate it).
    mem: Option<Timeline>,
    /// Free memory while the dimension is unmaterialized (constant
    /// everywhere), and the base the dimension materializes from.
    mem_base: i64,
    /// Physical capacity bounds (invariant checks; `total_mem == 0`
    /// means the profile does not track memory at all and every
    /// vector operation degenerates to its scalar cores form).
    total: u64,
    total_mem: u64,
}

impl Default for AvailabilityProfile {
    /// The empty profile (see [`AvailabilityProfile::EMPTY`]) — what a
    /// fresh [`crate::sched::RoundScratch`] plan starts as before its
    /// first `copy_from`.
    fn default() -> AvailabilityProfile {
        AvailabilityProfile::EMPTY
    }
}

impl AvailabilityProfile {
    /// A profile carrying no planning information (unit tests of
    /// policies that want the legacy allocate-only admission). Every
    /// query reports zero availability and schedulers skip admission
    /// checks against it entirely.
    pub const EMPTY: AvailabilityProfile = AvailabilityProfile {
        cores: Timeline::EMPTY,
        mem: None,
        mem_base: 0,
        total: 0,
        total_mem: 0,
    };

    /// Overwrite `self` with `src`, reusing the existing breakpoint
    /// allocations — the per-round scratch-plan path: after warmup a
    /// dispatch round's "clone" of the shared timeline allocates nothing
    /// (the buffers only ever grow to the high-water mark). Semantically
    /// identical to `*self = src.clone()`.
    pub fn copy_from(&mut self, src: &AvailabilityProfile) {
        self.cores.points.clone_from(&src.cores.points);
        if let Some(s) = &src.mem {
            if let Some(d) = &mut self.mem {
                d.points.clone_from(&s.points);
            } else {
                self.mem = Some(s.clone());
            }
        } else {
            self.mem = None;
        }
        self.mem_base = src.mem_base;
        self.total = src.total;
        self.total_mem = src.total_mem;
    }

    /// Flat cores-only profile: `free` cores from `now` on, on a machine
    /// with `total` physical cores. Memory is untracked.
    pub fn new(now: u64, free: u64, total: u64) -> AvailabilityProfile {
        AvailabilityProfile {
            cores: Timeline::new(now, free as i64),
            mem: None,
            mem_base: 0,
            total,
            total_mem: 0,
        }
    }

    /// Flat multi-resource profile. A nonzero `total.memory_mb` turns
    /// memory tracking on; the memory timeline itself stays
    /// unmaterialized until the first memory-carrying hold.
    pub fn new_v(now: u64, free: ResourceVector, total: ResourceVector) -> AvailabilityProfile {
        AvailabilityProfile {
            cores: Timeline::new(now, free.cores as i64),
            mem: None,
            mem_base: free.memory_mb as i64,
            total: total.cores,
            total_mem: total.memory_mb,
        }
    }

    /// Rebuild the cores dimension from scratch: `free_now` cores at
    /// `now` plus signed capacity deltas at future instants (a running
    /// job's release is `(est_end, +cores)`, a pending reservation is
    /// `(start, -cores)` and `(end, +cores)`, a failed node's repair is
    /// `(t, +cores)`). This is the resync path for rare capacity
    /// transitions and the oracle the incremental maintenance is
    /// property-tested against. Any materialized memory dimension is
    /// dropped (cores-only resync).
    pub fn rebuild(&mut self, now: u64, free_now: u64, deltas: Vec<(u64, i64)>) {
        self.cores.rebuild(now, free_now as i64, deltas);
        self.mem = None;
    }

    /// Multi-resource resync: both dimensions from authoritative state.
    /// The memory dimension materializes only when `mem_deltas` carries
    /// a nonzero entry — a memory-tracking profile over a workload with
    /// no memory demands keeps paying nothing.
    pub fn rebuild_v(
        &mut self,
        now: u64,
        free: ResourceVector,
        deltas: Vec<(u64, i64)>,
        mem_deltas: Vec<(u64, i64)>,
    ) {
        self.cores.rebuild(now, free.cores as i64, deltas);
        self.mem_base = free.memory_mb as i64;
        if self.total_mem > 0 && mem_deltas.iter().any(|d| d.1 != 0) {
            let tl = self.mem.get_or_insert(Timeline::EMPTY);
            tl.rebuild(now, free.memory_mb as i64, mem_deltas);
        } else {
            self.mem = None;
        }
    }

    /// Convenience constructor from `(release_time, cores)` pairs — the
    /// shape scheduler unit tests and benches speak.
    pub fn from_releases(
        now: u64,
        free_now: u64,
        total: u64,
        releases: &[(u64, u64)],
    ) -> AvailabilityProfile {
        let mut p = AvailabilityProfile::new(now, free_now, total);
        p.rebuild(now, free_now, releases.iter().map(|&(t, c)| (t, c as i64)).collect());
        p
    }

    /// Physical core-capacity bound.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Whether the profile tracks a memory dimension at all (set by
    /// [`AvailabilityProfile::new_v`] with nonzero total memory). When
    /// false, every `_v` operation ignores `memory_mb` — the guarantee
    /// that keeps cores-only configurations bit-identical to the scalar
    /// planner.
    pub fn tracks_memory(&self) -> bool {
        self.total_mem > 0
    }

    /// Whether the lazy memory timeline has actually been materialized
    /// (observability for the zero-cost pin in the bench and tests).
    pub fn has_memory_dimension(&self) -> bool {
        self.mem.is_some()
    }

    /// Number of cores-dimension breakpoints (memory/perf observability).
    pub fn len(&self) -> usize {
        self.cores.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cores.points.is_empty()
    }

    /// Raw cores-dimension breakpoints (tests and benches).
    pub fn points(&self) -> &[(u64, i64)] {
        &self.cores.points
    }

    /// Raw memory-dimension breakpoints, if materialized.
    pub fn mem_points(&self) -> Option<&[(u64, i64)]> {
        self.mem.as_ref().map(|t| t.points.as_slice())
    }

    /// Drop history before `now` in every active dimension.
    pub fn advance(&mut self, now: u64) {
        self.cores.advance(now);
        if let Some(m) = self.mem.as_mut() {
            m.advance(now);
        }
    }

    // ----- scalar (cores-dimension) API, unchanged from the scalar
    // planner: every caller that speaks cores keeps compiling and
    // behaving identically -----

    /// A job (or any occupant) takes `cores` over `[from, until)`.
    pub fn hold(&mut self, from: u64, until: u64, cores: u64) {
        self.cores.apply(from, until, -(cores as i64));
    }

    /// Exact inverse of [`AvailabilityProfile::hold`] over the remaining
    /// window: the occupant left at `from`, earlier than planned.
    pub fn release(&mut self, from: u64, until: u64, cores: u64) {
        self.cores.apply(from, until, cores as i64);
    }

    /// Plan a future advance reservation: `cores` unavailable over
    /// `[start, end)`.
    pub fn add_reservation_hold(&mut self, start: u64, end: u64, cores: u64) {
        self.cores.apply(start, end, -(cores as i64));
    }

    /// Capacity leaves service over `[from, until)` (node failure with a
    /// known repair time, a draining window, ...).
    pub fn remove_node_capacity(&mut self, from: u64, until: u64, cores: u64) {
        self.cores.apply(from, until, -(cores as i64));
    }

    /// Exact inverse of [`AvailabilityProfile::remove_node_capacity`]
    /// over the remaining window (e.g. a node repaired earlier than the
    /// drawn repair time).
    pub fn restore_node_capacity(&mut self, from: u64, until: u64, cores: u64) {
        self.cores.apply(from, until, cores as i64);
    }

    /// Free cores at instant `t`, clamped at zero. Instants before the
    /// profile origin read the origin segment (the timeline carries no
    /// history — callers plan from `now` forward).
    pub fn free_at(&self, t: u64) -> u64 {
        self.cores.free_at(t)
    }

    /// Free memory at instant `t` (clamped at zero). `u64::MAX` when the
    /// profile does not track memory — an untracked dimension never
    /// constrains.
    pub fn free_memory_at(&self, t: u64) -> u64 {
        if !self.tracks_memory() {
            return u64::MAX;
        }
        match &self.mem {
            Some(m) => m.free_at(t),
            None => self.mem_base.max(0) as u64,
        }
    }

    /// Whether `cores` are free throughout `[from, from + duration)`.
    pub fn can_place(&self, from: u64, duration: u64, cores: u64) -> bool {
        self.cores.can_place(from, duration, cores)
    }

    /// Earliest time >= `from` at which `cores` are free continuously
    /// for `duration`. `None` only when the request exceeds the
    /// profile's eventual capacity (infeasible job).
    pub fn earliest_slot(&self, from: u64, cores: u64, duration: u64) -> Option<u64> {
        self.cores.earliest_slot(from, cores, duration)
    }

    // ----- vector API: the same four verbs over multi-resource
    // demands. With memory untracked (or a zero memory demand) each is
    // exactly its scalar counterpart. -----

    /// The memory timeline, materializing it on first use.
    fn mem_timeline(&mut self) -> &mut Timeline {
        let origin = self.cores.points.first().map(|p| p.0).unwrap_or(0);
        let base = self.mem_base;
        self.mem.get_or_insert_with(|| Timeline::new(origin, base))
    }

    /// A demand takes `d` over `[from, until)` — the vector form of
    /// [`AvailabilityProfile::hold`] (also used for reservation and
    /// capacity-outage windows, which are algebraically identical).
    pub fn hold_v(&mut self, from: u64, until: u64, d: ResourceVector) {
        self.cores.apply(from, until, -(d.cores as i64));
        if self.total_mem > 0 && d.memory_mb > 0 {
            self.mem_timeline().apply(from, until, -(d.memory_mb as i64));
        }
    }

    /// Exact inverse of [`AvailabilityProfile::hold_v`] over the
    /// remaining window.
    pub fn release_v(&mut self, from: u64, until: u64, d: ResourceVector) {
        self.cores.apply(from, until, d.cores as i64);
        if self.total_mem > 0 && d.memory_mb > 0 {
            self.mem_timeline().apply(from, until, d.memory_mb as i64);
        }
    }

    /// Whether demand `d` fits throughout `[from, from + duration)` in
    /// every active dimension.
    pub fn can_place_v(&self, from: u64, duration: u64, d: ResourceVector) -> bool {
        if !self.cores.can_place(from, duration, d.cores) {
            return false;
        }
        if !self.tracks_memory() || d.memory_mb == 0 {
            return true;
        }
        match &self.mem {
            Some(m) => m.can_place(from, duration, d.memory_mb),
            None => d.memory_mb as i64 <= self.mem_base,
        }
    }

    /// Earliest time >= `from` at which demand `d` fits continuously for
    /// `duration` in every active dimension. Alternates between the
    /// per-dimension `earliest_slot` queries until they agree — each
    /// step jumps to a later breakpoint, so the loop is bounded by the
    /// total breakpoint count.
    pub fn earliest_slot_v(&self, from: u64, d: ResourceVector, duration: u64) -> Option<u64> {
        if !self.tracks_memory() || d.memory_mb == 0 {
            return self.cores.earliest_slot(from, d.cores, duration);
        }
        let mem = match &self.mem {
            Some(m) => m,
            None => {
                return if d.memory_mb as i64 <= self.mem_base {
                    self.cores.earliest_slot(from, d.cores, duration)
                } else {
                    None // constant memory shortfall: never fits
                };
            }
        };
        let mut t = from;
        loop {
            let a = self.cores.earliest_slot(t, d.cores, duration)?;
            let b = mem.earliest_slot(a, d.memory_mb, duration)?;
            if a == b {
                return Some(a);
            }
            debug_assert!(b > a, "earliest_slot went backwards");
            t = b;
        }
    }

    /// Whether no active dimension ever *loses* capacity over the
    /// timeline (pure release streams — no pending reservation or
    /// outage windows). On a monotone profile, fitting at `now` implies
    /// fitting forever, so blocking admission can skip the planning
    /// checks entirely and stay bit-identical to the classic
    /// allocate-only loop.
    pub fn is_monotone(&self) -> bool {
        self.cores.is_monotone() && self.mem.as_ref().map_or(true, |m| m.is_monotone())
    }

    /// Structural invariants (tests): strictly increasing times,
    /// canonical (no adjacent equal frees), free never above physical
    /// capacity — per active dimension.
    pub fn check_invariants(&self) -> bool {
        self.cores.check(self.total)
            && self.mem.as_ref().map_or(true, |m| m.check(self.total_mem))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_profile_reads_everywhere() {
        let p = AvailabilityProfile::new(10, 6, 8);
        assert_eq!(p.free_at(10), 6);
        assert_eq!(p.free_at(1_000_000), 6);
        assert!(p.check_invariants());
    }

    #[test]
    fn releases_accumulate() {
        let p = AvailabilityProfile::from_releases(0, 4, 12, &[(100, 4), (50, 2), (100, 2)]);
        assert_eq!(p.free_at(0), 4);
        assert_eq!(p.free_at(50), 6);
        assert_eq!(p.free_at(99), 6);
        assert_eq!(p.free_at(100), 12);
        assert!(p.check_invariants());
        assert!(p.is_monotone());
    }

    #[test]
    fn hold_and_release_are_inverse() {
        let mut p = AvailabilityProfile::from_releases(0, 4, 8, &[(100, 4)]);
        let before = p.points().to_vec();
        p.hold(0, 60, 3);
        assert_eq!(p.free_at(0), 1);
        assert_eq!(p.free_at(59), 1);
        assert_eq!(p.free_at(60), 4);
        p.release(0, 60, 3);
        assert_eq!(p.points(), &before[..]);
    }

    #[test]
    fn signed_over_commit_clamps_on_read() {
        let mut p = AvailabilityProfile::new(0, 4, 8);
        p.add_reservation_hold(10, 20, 8); // more than is free: window over-committed
        assert_eq!(p.free_at(10), 0);
        assert_eq!(p.points()[1].1, -4, "algebra stays exact internally");
        assert!(!p.is_monotone(), "a pending window is a capacity dip");
        p.restore_node_capacity(10, 20, 8);
        assert_eq!(p.free_at(10), 4);
        assert_eq!(p.len(), 1);
    }

    #[test]
    fn earliest_slot_basic() {
        // 4 free now, +4 at t=100 (mirrors the old conservative profile test).
        let p = AvailabilityProfile::from_releases(0, 4, 8, &[(100, 4)]);
        assert_eq!(p.earliest_slot(0, 6, 50), Some(100));
        assert_eq!(p.earliest_slot(0, 4, 1000), Some(0));
        assert_eq!(p.earliest_slot(0, 100, 10), None);
    }

    #[test]
    fn earliest_slot_skips_windows() {
        // Free 8, but a reservation takes everything over [50, 150).
        let mut p = AvailabilityProfile::new(0, 8, 8);
        p.add_reservation_hold(50, 150, 8);
        // A 10-tick 4-core job fits before the window...
        assert_eq!(p.earliest_slot(0, 4, 10), Some(0));
        // ...but a 60-tick job would collide: earliest slot is after it.
        assert_eq!(p.earliest_slot(0, 4, 60), Some(150));
        // From inside the window, everything waits for its end.
        assert_eq!(p.earliest_slot(70, 1, 1), Some(150));
    }

    #[test]
    fn earliest_slot_needs_contiguous_window() {
        // Free dips at [30, 40): a 35-tick window starting at 0 fails,
        // the next candidate is 40.
        let mut p = AvailabilityProfile::new(0, 8, 8);
        p.hold(30, 40, 6);
        assert_eq!(p.earliest_slot(0, 4, 35), Some(40));
        assert_eq!(p.earliest_slot(0, 2, 35), Some(0));
    }

    #[test]
    fn can_place_matches_earliest_slot_at_from() {
        let mut p = AvailabilityProfile::new(0, 8, 8);
        p.add_reservation_hold(30, 130, 8);
        assert!(p.can_place(0, 30, 8));
        assert!(!p.can_place(0, 31, 1));
        assert!(p.can_place(130, 1_000_000, 8));
        assert!(p.can_place(0, 0, 99), "empty window always fits");
    }

    #[test]
    fn advance_trims_history() {
        let mut p = AvailabilityProfile::from_releases(0, 2, 8, &[(10, 2), (20, 4)]);
        p.advance(15);
        assert_eq!(p.points()[0], (15, 4));
        assert_eq!(p.free_at(15), 4);
        assert_eq!(p.free_at(20), 8);
        assert!(p.check_invariants());
        // Advancing before the first point is a no-op.
        p.advance(3);
        assert_eq!(p.points()[0], (15, 4));
    }

    #[test]
    fn rebuild_merges_past_deltas_into_base() {
        let mut p = AvailabilityProfile::new(0, 0, 8);
        p.rebuild(100, 4, vec![(50, 4), (200, 4), (200, -2)]);
        assert_eq!(p.free_at(100), 8, "past release merges into the base");
        assert_eq!(p.free_at(200), 10);
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn split_reserve_is_stable() {
        // Mirrors the old conservative profile split test.
        let mut p = AvailabilityProfile::from_releases(10, 8, 16, &[(20, 4), (30, 4)]);
        p.hold(15, 25, 2);
        assert_eq!(p.free_at(10), 8);
        assert_eq!(p.free_at(15), 6);
        assert_eq!(p.free_at(20), 10);
        assert_eq!(p.free_at(25), 12);
        assert_eq!(p.free_at(30), 16);
        assert!(p.check_invariants());
    }

    // ----- multi-resource behaviour -----

    fn mem_profile(free_c: u64, free_m: u64) -> AvailabilityProfile {
        AvailabilityProfile::new_v(
            0,
            ResourceVector::new(free_c, free_m),
            ResourceVector::new(free_c, free_m),
        )
    }

    #[test]
    fn memory_dimension_is_lazy() {
        let mut p = mem_profile(8, 1000);
        assert!(p.tracks_memory());
        assert!(!p.has_memory_dimension());
        // Cores-only holds never materialize it.
        p.hold_v(0, 50, ResourceVector::cores_only(4));
        assert!(!p.has_memory_dimension());
        assert_eq!(p.free_memory_at(10), 1000);
        // The first memory-carrying hold does.
        p.hold_v(0, 50, ResourceVector::new(2, 600));
        assert!(p.has_memory_dimension());
        assert_eq!(p.free_memory_at(10), 400);
        assert_eq!(p.free_memory_at(50), 1000);
        assert!(p.check_invariants());
    }

    #[test]
    fn untracked_memory_never_constrains() {
        let p = AvailabilityProfile::new(0, 8, 8);
        assert!(!p.tracks_memory());
        let d = ResourceVector::new(4, 1_000_000);
        assert!(p.can_place_v(0, 100, d));
        assert_eq!(p.earliest_slot_v(0, d, 100), Some(0));
        assert_eq!(p.free_memory_at(0), u64::MAX);
    }

    #[test]
    fn earliest_slot_v_waits_for_memory() {
        // 8 cores free throughout; memory blocked until t=100.
        let mut p = mem_profile(8, 1000);
        p.hold_v(0, 100, ResourceVector::new(0, 900));
        let d = ResourceVector::new(4, 500);
        assert!(!p.can_place_v(0, 50, d));
        assert_eq!(p.earliest_slot_v(0, d, 50), Some(100));
        // A low-memory demand fits immediately.
        assert_eq!(p.earliest_slot_v(0, ResourceVector::new(4, 100), 50), Some(0));
        // More memory than the machine has: infeasible.
        assert_eq!(p.earliest_slot_v(0, ResourceVector::new(1, 2000), 1), None);
    }

    #[test]
    fn earliest_slot_v_intersects_dimensions() {
        // Cores free at t=50, memory free at t=80: joint slot is 80.
        let mut p = mem_profile(8, 1000);
        p.hold_v(0, 50, ResourceVector::cores_only(8));
        p.hold_v(0, 80, ResourceVector::new(0, 800));
        let d = ResourceVector::new(4, 500);
        assert_eq!(p.earliest_slot_v(0, d, 10), Some(80));
        // And the other way round (memory frees first).
        let mut q = mem_profile(8, 1000);
        q.hold_v(0, 80, ResourceVector::cores_only(8));
        q.hold_v(0, 50, ResourceVector::new(0, 800));
        assert_eq!(q.earliest_slot_v(0, d, 10), Some(80));
    }

    #[test]
    fn vector_hold_release_inverse_restores_both_dims() {
        let mut p = mem_profile(8, 1000);
        let before = p.points().to_vec();
        let d = ResourceVector::new(4, 600);
        p.hold_v(10, 60, d);
        assert!(!p.can_place_v(10, 10, ResourceVector::new(0, 500)));
        p.release_v(10, 60, d);
        assert_eq!(p.points(), &before[..]);
        // The materialized dimension coalesces back to a flat line.
        assert_eq!(p.mem_points().unwrap().len(), 1);
        assert_eq!(p.free_memory_at(10), 1000);
    }

    #[test]
    fn copy_from_matches_clone_semantics() {
        let mut src = mem_profile(8, 1000);
        src.hold_v(10, 60, ResourceVector::new(4, 600));
        let mut dst = AvailabilityProfile::EMPTY;
        dst.copy_from(&src);
        assert_eq!(dst.points(), src.points());
        assert_eq!(dst.mem_points(), src.mem_points());
        assert_eq!(dst.free_memory_at(20), src.free_memory_at(20));
        assert!(dst.check_invariants());
        // Overwriting with a memory-free profile drops the dimension.
        let flat = AvailabilityProfile::new(0, 4, 8);
        dst.copy_from(&flat);
        assert!(!dst.has_memory_dimension());
        assert_eq!(dst.points(), flat.points());
        assert_eq!(dst.total(), 8);
    }

    #[test]
    fn rebuild_v_materializes_only_on_memory_deltas() {
        let mut p = mem_profile(8, 1000);
        p.rebuild_v(0, ResourceVector::new(4, 1000), vec![(100, 4)], Vec::new());
        assert!(!p.has_memory_dimension(), "no memory deltas: stay lazy");
        assert_eq!(p.free_at(100), 8);
        p.rebuild_v(0, ResourceVector::new(4, 200), vec![(100, 4)], vec![(100, 800)]);
        assert!(p.has_memory_dimension());
        assert_eq!(p.free_memory_at(0), 200);
        assert_eq!(p.free_memory_at(100), 1000);
        assert!(p.check_invariants());
    }
}
