//! The availability timeline — the planning core every forward-looking
//! scheduling decision reads from (tentpole of the unified planning
//! refactor).
//!
//! [`AvailabilityProfile`] is an incremental, time-indexed free-core
//! step function: a breakpoint list `(time, free)` where `free` holds
//! until the next breakpoint and the last segment extends to infinity.
//! It is owned by the simulation core (`sim::SchedulerComponent`), which
//! updates it *incrementally* on job start/finish, reservation
//! claim/release and node failure/repair instead of rebuilding it from
//! sorted release vectors every scheduling round. Policies receive it
//! read-only through `sched::SchedInput::profile`:
//!
//! * EASY backfilling derives its shadow time and extra cores from
//!   [`AvailabilityProfile::earliest_slot`] and admission-checks
//!   candidates with [`AvailabilityProfile::can_place`] — which is what
//!   makes backfill respect *future* advance reservations and
//!   down/draining capacity windows;
//! * conservative backfilling clones the profile into a per-round
//!   scratch plan and lays every queued job's reservation onto it;
//! * the preemption layer and the fault injector feed capacity windows
//!   in through the mutators ([`AvailabilityProfile::hold`],
//!   [`AvailabilityProfile::add_reservation_hold`],
//!   [`AvailabilityProfile::remove_node_capacity`] /
//!   [`AvailabilityProfile::restore_node_capacity`]).
//!
//! `free` is stored *signed*: planning holds (e.g. an advance
//! reservation over a window where jobs are still draining) may
//! transiently over-commit a window. Readers clamp to zero — an
//! over-committed window simply offers no cores — while the signed
//! algebra keeps every `hold`/`release` pair an exact inverse, the
//! invariant the incremental maintenance relies on
//! (property-tested in rust/tests/prop_profile.rs).
//!
//! The profile is a *planning estimate*, trusted the way backfilling
//! trusts user runtime estimates: a job that overruns its estimate
//! appears free in the profile before its cores actually return
//! (exactly as the per-round rebuild it replaces behaved). Admission is
//! therefore always re-checked against the exact [`super::Cluster`]
//! accounting; the profile only decides what is *worth* checking.

/// Incremental future free-core timeline.
///
/// Complexity: `earliest_slot`/`can_place` are O(log n + k) in the
/// number of breakpoints (k = segments actually inspected); the
/// mutators are O(n) worst case for the breakpoint insert but touch
/// only the affected span — there is no per-round sort or rebuild.
#[derive(Debug, Clone)]
pub struct AvailabilityProfile {
    /// `(time, free)` breakpoints; times strictly increasing, adjacent
    /// `free` values distinct (canonical form), last segment open-ended.
    points: Vec<(u64, i64)>,
    /// Physical capacity bound (for invariant checks only).
    total: u64,
}

impl AvailabilityProfile {
    /// A profile carrying no planning information (policies that ignore
    /// the timeline — FCFS/SJF/LJF/BestFit — and their unit tests).
    /// Every query reports zero availability.
    pub const EMPTY: AvailabilityProfile = AvailabilityProfile { points: Vec::new(), total: 0 };

    /// Flat profile: `free` cores from `now` on, on a machine with
    /// `total` physical cores.
    pub fn new(now: u64, free: u64, total: u64) -> AvailabilityProfile {
        AvailabilityProfile { points: vec![(now, free as i64)], total }
    }

    /// Rebuild from scratch: `free_now` cores at `now` plus signed
    /// capacity deltas at future instants (a running job's release is
    /// `(est_end, +cores)`, a pending reservation is `(start, -cores)`
    /// and `(end, +cores)`, a failed node's repair is `(t, +cores)`).
    /// Deltas at or before `now` merge into the base value, mirroring
    /// the per-round rebuild this structure replaces. This is the
    /// resync path for rare capacity transitions and the oracle the
    /// incremental maintenance is property-tested against.
    pub fn rebuild(&mut self, now: u64, free_now: u64, mut deltas: Vec<(u64, i64)>) {
        deltas.retain(|d| d.1 != 0);
        deltas.sort_unstable();
        self.points.clear();
        self.points.push((now, free_now as i64));
        for (t, d) in deltas {
            let t = t.max(now);
            let last = *self.points.last().unwrap();
            if t == last.0 {
                self.points.last_mut().unwrap().1 = last.1 + d;
            } else {
                self.points.push((t, last.1 + d));
            }
        }
        self.points.dedup_by(|a, b| a.1 == b.1);
    }

    /// Convenience constructor from `(release_time, cores)` pairs — the
    /// shape scheduler unit tests and benches speak.
    pub fn from_releases(
        now: u64,
        free_now: u64,
        total: u64,
        releases: &[(u64, u64)],
    ) -> AvailabilityProfile {
        let mut p = AvailabilityProfile::new(now, free_now, total);
        p.rebuild(now, free_now, releases.iter().map(|&(t, c)| (t, c as i64)).collect());
        p
    }

    /// Physical capacity bound.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Number of breakpoints (memory/perf observability).
    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Raw breakpoints (tests and benches).
    pub fn points(&self) -> &[(u64, i64)] {
        &self.points
    }

    /// Drop history before `now`: breakpoints at or before `now` merge
    /// into the head segment. O(k) in the breakpoints trimmed.
    pub fn advance(&mut self, now: u64) {
        let i = self.seg_at(now);
        if i > 0 {
            self.points.drain(..i);
        }
        if let Some(p) = self.points.first_mut() {
            if p.0 < now {
                p.0 = now;
            }
        }
    }

    /// Index of the segment containing `t` (the last breakpoint at or
    /// before `t`); the first segment when `t` precedes the profile.
    fn seg_at(&self, t: u64) -> usize {
        match self.points.binary_search_by_key(&t, |p| p.0) {
            Ok(i) => i,
            Err(0) => 0,
            Err(i) => i - 1,
        }
    }

    /// Insert a breakpoint at `t` (no-op if present or out of range).
    fn split_at(&mut self, t: u64) {
        if t == u64::MAX {
            return;
        }
        match self.points.binary_search_by_key(&t, |p| p.0) {
            Ok(_) => {}
            Err(0) => {} // before the profile origin; `apply` clips instead
            Err(i) => {
                let f = self.points[i - 1].1;
                self.points.insert(i, (t, f));
            }
        }
    }

    /// Add `delta` to every instant in `[from, until)`, keeping the
    /// breakpoint list canonical. Interior points shift together, so
    /// only the two window boundaries can need coalescing — the whole
    /// operation touches O(log n + window) points, never the full list.
    fn apply(&mut self, from: u64, until: u64, delta: i64) {
        if delta == 0 || self.points.is_empty() {
            return;
        }
        let from = from.max(self.points[0].0);
        if from >= until {
            return;
        }
        self.split_at(from);
        self.split_at(until);
        let a = match self.points.binary_search_by_key(&from, |p| p.0) {
            Ok(i) => i,
            Err(_) => unreachable!("split_at(from) must leave a breakpoint at from"),
        };
        let mut b = a;
        while b < self.points.len() && self.points[b].0 < until {
            self.points[b].1 += delta;
            b += 1;
        }
        // Coalesce the `until` boundary first (does not shift `a`),
        // then the `from` boundary.
        if b < self.points.len() && self.points[b].1 == self.points[b - 1].1 {
            self.points.remove(b);
        }
        if a > 0 && self.points[a].1 == self.points[a - 1].1 {
            self.points.remove(a);
        }
    }

    /// A job (or any occupant) takes `cores` over `[from, until)`.
    pub fn hold(&mut self, from: u64, until: u64, cores: u64) {
        self.apply(from, until, -(cores as i64));
    }

    /// Exact inverse of [`AvailabilityProfile::hold`] over the remaining
    /// window: the occupant left at `from`, earlier than planned.
    pub fn release(&mut self, from: u64, until: u64, cores: u64) {
        self.apply(from, until, cores as i64);
    }

    /// Plan a future advance reservation: `cores` unavailable over
    /// `[start, end)`.
    pub fn add_reservation_hold(&mut self, start: u64, end: u64, cores: u64) {
        self.apply(start, end, -(cores as i64));
    }

    /// Capacity leaves service over `[from, until)` (node failure with a
    /// known repair time, a draining window, ...).
    pub fn remove_node_capacity(&mut self, from: u64, until: u64, cores: u64) {
        self.apply(from, until, -(cores as i64));
    }

    /// Exact inverse of [`AvailabilityProfile::remove_node_capacity`]
    /// over the remaining window (e.g. a node repaired earlier than the
    /// drawn repair time).
    pub fn restore_node_capacity(&mut self, from: u64, until: u64, cores: u64) {
        self.apply(from, until, cores as i64);
    }

    /// Free cores at instant `t`, clamped at zero. Instants before the
    /// profile origin read the origin segment (the timeline carries no
    /// history — callers plan from `now` forward).
    pub fn free_at(&self, t: u64) -> u64 {
        if self.points.is_empty() {
            return 0;
        }
        self.points[self.seg_at(t)].1.max(0) as u64
    }

    /// Whether `cores` are free throughout `[from, from + duration)`.
    /// The pre-origin part of the window, if any, is the past and is
    /// ignored — only the portion the timeline covers is checked
    /// (mirrors `earliest_slot`'s origin clamp).
    pub fn can_place(&self, from: u64, duration: u64, cores: u64) -> bool {
        if duration == 0 {
            return true;
        }
        if self.points.is_empty() {
            return false;
        }
        let end = from.saturating_add(duration);
        let from = from.max(self.points[0].0);
        if from >= end {
            return true; // window entirely before the origin
        }
        let c = cores as i64;
        let mut i = self.seg_at(from);
        loop {
            if self.points[i].1 < c {
                return false;
            }
            let seg_end = self.points.get(i + 1).map(|p| p.0).unwrap_or(u64::MAX);
            if seg_end >= end {
                return true;
            }
            i += 1;
        }
    }

    /// Earliest time >= `from` at which `cores` are free continuously
    /// for `duration`. Binary-searches to the starting segment and scans
    /// forward — O(log n + k) — instead of the quadratic
    /// candidate-times-x-segments scan the old per-policy profile used.
    /// `None` only when the request exceeds the profile's eventual
    /// capacity (infeasible job).
    pub fn earliest_slot(&self, from: u64, cores: u64, duration: u64) -> Option<u64> {
        if self.points.is_empty() {
            return None;
        }
        let c = cores as i64;
        let duration = duration.max(1);
        let mut candidate = from.max(self.points[0].0);
        let mut i = self.seg_at(candidate);
        loop {
            let free = self.points[i].1;
            let seg_end = self.points.get(i + 1).map(|p| p.0).unwrap_or(u64::MAX);
            if free < c {
                if seg_end == u64::MAX {
                    return None; // blocked forever: infeasible request
                }
                candidate = seg_end;
            } else if seg_end == u64::MAX || seg_end >= candidate.saturating_add(duration) {
                return Some(candidate);
            }
            i += 1;
            debug_assert!(i < self.points.len(), "open-ended tail must terminate the scan");
        }
    }

    /// Structural invariants (tests): strictly increasing times,
    /// canonical (no adjacent equal frees), free never above physical
    /// capacity.
    pub fn check_invariants(&self) -> bool {
        !self.points.is_empty()
            && self.points.windows(2).all(|w| w[0].0 < w[1].0 && w[0].1 != w[1].1)
            && self.points.iter().all(|p| p.1 <= self.total as i64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_profile_reads_everywhere() {
        let p = AvailabilityProfile::new(10, 6, 8);
        assert_eq!(p.free_at(10), 6);
        assert_eq!(p.free_at(1_000_000), 6);
        assert!(p.check_invariants());
    }

    #[test]
    fn releases_accumulate() {
        let p = AvailabilityProfile::from_releases(0, 4, 12, &[(100, 4), (50, 2), (100, 2)]);
        assert_eq!(p.free_at(0), 4);
        assert_eq!(p.free_at(50), 6);
        assert_eq!(p.free_at(99), 6);
        assert_eq!(p.free_at(100), 12);
        assert!(p.check_invariants());
    }

    #[test]
    fn hold_and_release_are_inverse() {
        let mut p = AvailabilityProfile::from_releases(0, 4, 8, &[(100, 4)]);
        let before = p.points().to_vec();
        p.hold(0, 60, 3);
        assert_eq!(p.free_at(0), 1);
        assert_eq!(p.free_at(59), 1);
        assert_eq!(p.free_at(60), 4);
        p.release(0, 60, 3);
        assert_eq!(p.points(), &before[..]);
    }

    #[test]
    fn signed_over_commit_clamps_on_read() {
        let mut p = AvailabilityProfile::new(0, 4, 8);
        p.add_reservation_hold(10, 20, 8); // more than is free: window over-committed
        assert_eq!(p.free_at(10), 0);
        assert_eq!(p.points()[1].1, -4, "algebra stays exact internally");
        p.restore_node_capacity(10, 20, 8);
        assert_eq!(p.free_at(10), 4);
        assert_eq!(p.len(), 1);
    }

    #[test]
    fn earliest_slot_basic() {
        // 4 free now, +4 at t=100 (mirrors the old conservative profile test).
        let p = AvailabilityProfile::from_releases(0, 4, 8, &[(100, 4)]);
        assert_eq!(p.earliest_slot(0, 6, 50), Some(100));
        assert_eq!(p.earliest_slot(0, 4, 1000), Some(0));
        assert_eq!(p.earliest_slot(0, 100, 10), None);
    }

    #[test]
    fn earliest_slot_skips_windows() {
        // Free 8, but a reservation takes everything over [50, 150).
        let mut p = AvailabilityProfile::new(0, 8, 8);
        p.add_reservation_hold(50, 150, 8);
        // A 10-tick 4-core job fits before the window...
        assert_eq!(p.earliest_slot(0, 4, 10), Some(0));
        // ...but a 60-tick job would collide: earliest slot is after it.
        assert_eq!(p.earliest_slot(0, 4, 60), Some(150));
        // From inside the window, everything waits for its end.
        assert_eq!(p.earliest_slot(70, 1, 1), Some(150));
    }

    #[test]
    fn earliest_slot_needs_contiguous_window() {
        // Free dips at [30, 40): a 35-tick window starting at 0 fails,
        // the next candidate is 40.
        let mut p = AvailabilityProfile::new(0, 8, 8);
        p.hold(30, 40, 6);
        assert_eq!(p.earliest_slot(0, 4, 35), Some(40));
        assert_eq!(p.earliest_slot(0, 2, 35), Some(0));
    }

    #[test]
    fn can_place_matches_earliest_slot_at_from() {
        let mut p = AvailabilityProfile::new(0, 8, 8);
        p.add_reservation_hold(30, 130, 8);
        assert!(p.can_place(0, 30, 8));
        assert!(!p.can_place(0, 31, 1));
        assert!(p.can_place(130, 1_000_000, 8));
        assert!(p.can_place(0, 0, 99), "empty window always fits");
    }

    #[test]
    fn advance_trims_history() {
        let mut p = AvailabilityProfile::from_releases(0, 2, 8, &[(10, 2), (20, 4)]);
        p.advance(15);
        assert_eq!(p.points()[0], (15, 4));
        assert_eq!(p.free_at(15), 4);
        assert_eq!(p.free_at(20), 8);
        assert!(p.check_invariants());
        // Advancing before the first point is a no-op.
        p.advance(3);
        assert_eq!(p.points()[0], (15, 4));
    }

    #[test]
    fn rebuild_merges_past_deltas_into_base() {
        let mut p = AvailabilityProfile::new(0, 0, 8);
        p.rebuild(100, 4, vec![(50, 4), (200, 4), (200, -2)]);
        assert_eq!(p.free_at(100), 8, "past release merges into the base");
        assert_eq!(p.free_at(200), 10);
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn split_reserve_is_stable() {
        // Mirrors the old conservative profile split test.
        let mut p = AvailabilityProfile::from_releases(10, 8, 16, &[(20, 4), (30, 4)]);
        p.hold(15, 25, 2);
        assert_eq!(p.free_at(10), 8);
        assert_eq!(p.free_at(15), 6);
        assert_eq!(p.free_at(20), 10);
        assert_eq!(p.free_at(25), 12);
        assert_eq!(p.free_at(30), 16);
        assert!(p.check_invariants());
    }
}
