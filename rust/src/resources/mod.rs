//! Resource management: nodes, core/memory accounting, allocation.
//!
//! Implements the paper's Algorithm 1 (allocate/deallocate with a core
//! pool) generalized to per-node accounting so FCFS-BestFit has real
//! fragmentation to optimize against. The cluster tracks free cores and
//! memory per node; allocations record exactly what they took so release
//! is always exact (conservation invariant, property-tested in
//! `rust/tests/prop_resources.rs`).

pub mod profile;
pub mod topology;
pub mod vector;

pub use profile::AvailabilityProfile;
pub use topology::Topology;
pub use vector::ResourceVector;

use crate::job::{Job, JobId};

/// Node lifecycle state (fault/reservation subsystem).
///
/// Only `Up` nodes accept new allocations. `Draining` nodes finish their
/// running jobs but take no new work; `Down` nodes are failed (any
/// occupant was killed when the failure hit); `Reserved` nodes are held
/// idle for an advance reservation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum NodeState {
    #[default]
    Up,
    Draining,
    Down,
    Reserved,
}

/// One compute node.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: usize,
    pub cores: u64,
    pub free_cores: u64,
    pub memory_mb: u64,
    pub free_memory_mb: u64,
    pub state: NodeState,
}

impl Node {
    pub fn new(id: usize, cores: u64, memory_mb: u64) -> Node {
        Node {
            id,
            cores,
            free_cores: cores,
            memory_mb,
            free_memory_mb: memory_mb,
            state: NodeState::Up,
        }
    }

    pub fn busy_cores(&self) -> u64 {
        self.cores - self.free_cores
    }

    pub fn is_idle(&self) -> bool {
        self.free_cores == self.cores
    }

    /// Whether the node accepts new allocations.
    pub fn is_available(&self) -> bool {
        self.state == NodeState::Up
    }
}

/// How nodes are picked for a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AllocPolicy {
    /// Scan nodes in id order, take what's free (baseline).
    #[default]
    FirstFit,
    /// Prefer the node whose free-core count most closely matches the
    /// request (minimizes leftover slack); falls back to packing the
    /// smallest holes first when the job spans nodes.
    BestFit,
}

/// A granted allocation: exactly which cores/memory were taken where.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allocation {
    pub job_id: JobId,
    /// (node id, cores taken, memory taken).
    pub taken: Vec<(usize, u64, u64)>,
}

impl Allocation {
    pub fn cores(&self) -> u64 {
        self.taken.iter().map(|t| t.1).sum()
    }

    /// Memory actually taken, summed over nodes (>= the job's request:
    /// per-node shares round up).
    pub fn memory_mb(&self) -> u64 {
        self.taken.iter().map(|t| t.2).sum()
    }

    /// Aggregate footprint of this allocation as a planning vector.
    pub fn demand(&self) -> ResourceVector {
        ResourceVector::new(self.cores(), self.memory_mb())
    }

    pub fn node_ids(&self) -> Vec<usize> {
        self.taken.iter().map(|t| t.0).collect()
    }
}

/// The machine: a vector of nodes plus cached aggregates.
///
/// `free_cores` counts free cores on `Up` nodes only (the schedulable
/// pool); `busy_cores` counts allocated cores on any node; `down_cores`
/// counts the physical capacity of `Down` nodes. All three are cached and
/// kept consistent by `allocate`/`release`/`set_node_state`
/// (`check_invariants` cross-checks against the per-node truth).
#[derive(Debug, Clone)]
pub struct Cluster {
    nodes: Vec<Node>,
    total_cores: u64,
    free_cores: u64,
    busy_cores: u64,
    down_cores: u64,
}

impl Cluster {
    /// Homogeneous cluster: `n` nodes of `cores_per_node` cores and
    /// `mem_per_node` MB each.
    pub fn homogeneous(n: usize, cores_per_node: u64, mem_per_node: u64) -> Cluster {
        let nodes: Vec<Node> =
            (0..n).map(|i| Node::new(i, cores_per_node, mem_per_node)).collect();
        let total = cores_per_node * n as u64;
        Cluster { nodes, total_cores: total, free_cores: total, busy_cores: 0, down_cores: 0 }
    }

    /// Heterogeneous cluster from explicit (cores, memory) pairs.
    pub fn heterogeneous(specs: &[(u64, u64)]) -> Cluster {
        let nodes: Vec<Node> = specs
            .iter()
            .enumerate()
            .map(|(i, &(c, m))| Node::new(i, c, m))
            .collect();
        let total = nodes.iter().map(|n| n.cores).sum();
        Cluster { nodes, total_cores: total, free_cores: total, busy_cores: 0, down_cores: 0 }
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn total_cores(&self) -> u64 {
        self.total_cores
    }

    /// Free cores on `Up` nodes (the schedulable pool).
    pub fn free_cores(&self) -> u64 {
        self.free_cores
    }

    /// Cores currently allocated to jobs (on any node).
    pub fn busy_cores(&self) -> u64 {
        self.busy_cores
    }

    /// Physical cores on nodes that are not `Down`.
    pub fn available_cores(&self) -> u64 {
        self.total_cores - self.down_cores
    }

    /// Fraction of physical cores busy, in [0, 1].
    pub fn utilization(&self) -> f64 {
        if self.total_cores == 0 {
            0.0
        } else {
            self.busy_cores() as f64 / self.total_cores as f64
        }
    }

    /// Fraction of *non-failed* capacity busy (the paper-style metric an
    /// operator watches during an outage): busy / (total - down).
    pub fn effective_utilization(&self) -> f64 {
        let avail = self.available_cores();
        if avail == 0 {
            0.0
        } else {
            self.busy_cores() as f64 / avail as f64
        }
    }

    /// Change a node's lifecycle state, keeping the cached pools
    /// consistent: a node leaving `Up` removes its free cores from the
    /// schedulable pool, a node entering `Up` returns them.
    pub fn set_node_state(&mut self, id: usize, new: NodeState) {
        let old = self.nodes[id].state;
        if old == new {
            return;
        }
        if old == NodeState::Up {
            self.free_cores -= self.nodes[id].free_cores;
        }
        if new == NodeState::Up {
            self.free_cores += self.nodes[id].free_cores;
        }
        if old == NodeState::Down {
            self.down_cores -= self.nodes[id].cores;
        }
        if new == NodeState::Down {
            self.down_cores += self.nodes[id].cores;
        }
        self.nodes[id].state = new;
        debug_assert!(self.check_invariants());
    }

    pub fn node_state(&self, id: usize) -> NodeState {
        self.nodes[id].state
    }

    /// Node ids currently in `state`. Lazy — hot paths iterate without
    /// allocating; collect only when a snapshot is needed.
    pub fn nodes_in_state(&self, state: NodeState) -> impl Iterator<Item = usize> + '_ {
        self.nodes.iter().filter(move |n| n.state == state).map(|n| n.id)
    }

    /// Cores an advance reservation of `nodes` whole nodes will take out
    /// of service, for the availability planner. Which nodes the claim
    /// actually picks depends on load at claim time, so the planner uses
    /// the largest `nodes` capacities — it must not understate the hold
    /// (on the homogeneous machines the simulator builds this is exact).
    pub fn reservation_plan_cores(&self, nodes: usize) -> u64 {
        if nodes >= self.nodes.len() {
            return self.total_cores;
        }
        let mut caps: Vec<u64> = self.nodes.iter().map(|n| n.cores).collect();
        caps.sort_unstable_by(|a, b| b.cmp(a));
        caps[..nodes].iter().sum()
    }

    /// Memory analogue of [`Cluster::reservation_plan_cores`]: the
    /// largest `nodes` node memories (must not understate the hold).
    pub fn reservation_plan_mem(&self, nodes: usize) -> u64 {
        let mut caps: Vec<u64> = self.nodes.iter().map(|n| n.memory_mb).collect();
        if nodes >= caps.len() {
            return caps.iter().sum();
        }
        caps.sort_unstable_by(|a, b| b.cmp(a));
        caps[..nodes].iter().sum()
    }

    /// Physical memory across all nodes.
    pub fn total_memory_mb(&self) -> u64 {
        self.nodes.iter().map(|n| n.memory_mb).sum()
    }

    /// Free memory on `Up` nodes (the schedulable memory pool). Computed
    /// on demand — callers are the rare resync path and reporting, not
    /// the per-event hot path.
    pub fn free_memory_mb(&self) -> u64 {
        self.nodes
            .iter()
            .filter(|n| n.state == NodeState::Up)
            .map(|n| n.free_memory_mb)
            .sum()
    }

    /// Memory allocated to jobs (on any node).
    pub fn busy_memory_mb(&self) -> u64 {
        self.nodes.iter().map(|n| n.memory_mb - n.free_memory_mb).sum()
    }

    /// Fraction of physical memory busy, in [0, 1]; 0 when the machine
    /// tracks no memory.
    pub fn memory_utilization(&self) -> f64 {
        let total = self.total_memory_mb();
        if total == 0 {
            0.0
        } else {
            self.busy_memory_mb() as f64 / total as f64
        }
    }

    /// Nodes with at least one busy core (paper Fig 3(a) metric).
    pub fn occupied_nodes(&self) -> usize {
        self.nodes.iter().filter(|n| !n.is_idle()).count()
    }

    /// Per-node free cores as f32 (input to the XLA/native scorer).
    /// Non-`Up` nodes report zero free so no backend can place on them.
    pub fn free_vec(&self) -> Vec<f32> {
        self.nodes
            .iter()
            .map(|n| if n.is_available() { n.free_cores as f32 } else { 0.0 })
            .collect()
    }

    /// Whether `job` could ever run on this machine.
    pub fn feasible(&self, job: &Job) -> bool {
        job.cores <= self.total_cores
            && job.memory_mb <= self.nodes.iter().map(|n| n.memory_mb).sum::<u64>()
    }

    /// Whether `job` fits right now (cores only; memory is checked during
    /// placement because it is per-node).
    pub fn fits_now(&self, job: &Job) -> bool {
        job.cores <= self.free_cores
    }

    /// Memory the job needs on a node contributing `cores_on_node` of its
    /// `total_cores` cores (proportional share, rounded up).
    fn mem_share(job_mem: u64, cores_on_node: u64, total_cores: u64) -> u64 {
        if job_mem == 0 || total_cores == 0 {
            return 0;
        }
        job_mem.div_ceil(total_cores) * cores_on_node
    }

    /// Try to allocate `job` under `policy`. Returns `None` (and leaves the
    /// cluster untouched) if the job does not fit at this instant.
    pub fn allocate(&mut self, job: &Job, policy: AllocPolicy) -> Option<Allocation> {
        if !self.fits_now(job) || job.cores == 0 {
            return None;
        }
        let plan = match policy {
            AllocPolicy::FirstFit => self.plan_first_fit(job),
            AllocPolicy::BestFit => self.plan_best_fit(job),
        }?;
        // Commit.
        for &(nid, c, m) in &plan {
            let n = &mut self.nodes[nid];
            debug_assert!(n.is_available());
            debug_assert!(n.free_cores >= c && n.free_memory_mb >= m);
            n.free_cores -= c;
            n.free_memory_mb -= m;
        }
        self.free_cores -= job.cores;
        self.busy_cores += job.cores;
        Some(Allocation { job_id: job.id, taken: plan })
    }

    /// First-fit plan: scan nodes in id order.
    fn plan_first_fit(&self, job: &Job) -> Option<Vec<(usize, u64, u64)>> {
        self.plan_in_order(job, (0..self.nodes.len()).collect())
    }

    /// Best-fit plan. Single-node case: the fitting node with minimum
    /// leftover. Multi-node case: pack smallest free counts first.
    fn plan_best_fit(&self, job: &Job) -> Option<Vec<(usize, u64, u64)>> {
        // Single-node best fit.
        let mut best: Option<(u64, usize)> = None; // (slack, node)
        for n in &self.nodes {
            if n.is_available() && n.free_cores >= job.cores {
                let mem = Self::mem_share(job.memory_mb, job.cores, job.cores);
                if n.free_memory_mb < mem {
                    continue;
                }
                let slack = n.free_cores - job.cores;
                if best.map_or(true, |(s, _)| slack < s) {
                    best = Some((slack, n.id));
                }
            }
        }
        if let Some((_, nid)) = best {
            let mem = Self::mem_share(job.memory_mb, job.cores, job.cores);
            return Some(vec![(nid, job.cores, mem)]);
        }
        // Multi-node: smallest holes first (tightest packing).
        let mut order: Vec<usize> = (0..self.nodes.len())
            .filter(|&i| self.nodes[i].is_available() && self.nodes[i].free_cores > 0)
            .collect();
        order.sort_by_key(|&i| (self.nodes[i].free_cores, i));
        self.plan_in_order(job, order)
    }

    /// Greedy plan following `order`; `None` if cores or memory run short.
    fn plan_in_order(&self, job: &Job, order: Vec<usize>) -> Option<Vec<(usize, u64, u64)>> {
        let mut remaining = job.cores;
        let mut plan = Vec::new();
        for nid in order {
            if remaining == 0 {
                break;
            }
            let n = &self.nodes[nid];
            if !n.is_available() || n.free_cores == 0 {
                continue;
            }
            let take = remaining.min(n.free_cores);
            let mem = Self::mem_share(job.memory_mb, take, job.cores);
            if n.free_memory_mb < mem {
                continue; // node lacks memory for its share
            }
            plan.push((nid, take, mem));
            remaining -= take;
        }
        if remaining == 0 {
            Some(plan)
        } else {
            None
        }
    }

    /// Return an allocation's resources to the pool (Algorithm 1,
    /// deallocateResources). Cores on a node that has left `Up` since the
    /// allocation go back to the node but not to the schedulable pool —
    /// `set_node_state` already removed that node's free cores.
    pub fn release(&mut self, alloc: &Allocation) {
        for &(nid, c, m) in &alloc.taken {
            let n = &mut self.nodes[nid];
            n.free_cores += c;
            n.free_memory_mb += m;
            debug_assert!(n.free_cores <= n.cores, "over-release on node {nid}");
            debug_assert!(n.free_memory_mb <= n.memory_mb);
            if n.state == NodeState::Up {
                self.free_cores += c;
            }
            self.busy_cores -= c;
        }
        debug_assert!(self.free_cores <= self.total_cores);
    }

    /// Consistency check (used by tests and debug assertions): cached
    /// aggregates equal the per-node sums.
    pub fn check_invariants(&self) -> bool {
        let free_up: u64 = self
            .nodes
            .iter()
            .filter(|n| n.state == NodeState::Up)
            .map(|n| n.free_cores)
            .sum();
        let busy: u64 = self.nodes.iter().map(|n| n.cores - n.free_cores).sum();
        let down: u64 = self
            .nodes
            .iter()
            .filter(|n| n.state == NodeState::Down)
            .map(|n| n.cores)
            .sum();
        free_up == self.free_cores
            && busy == self.busy_cores
            && down == self.down_cores
            && self.free_cores <= self.total_cores
            && self.nodes.iter().all(|n| n.free_cores <= n.cores && n.free_memory_mb <= n.memory_mb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64, cores: u64) -> Job {
        Job::simple(id, 0, cores, 10)
    }

    #[test]
    fn homogeneous_setup() {
        let c = Cluster::homogeneous(4, 8, 1024);
        assert_eq!(c.total_cores(), 32);
        assert_eq!(c.free_cores(), 32);
        assert_eq!(c.num_nodes(), 4);
        assert_eq!(c.occupied_nodes(), 0);
        assert!(c.check_invariants());
    }

    #[test]
    fn first_fit_takes_in_node_order() {
        let mut c = Cluster::homogeneous(4, 8, 1024);
        let a = c.allocate(&job(1, 12), AllocPolicy::FirstFit).unwrap();
        assert_eq!(a.taken, vec![(0, 8, 0), (1, 4, 0)]);
        assert_eq!(c.free_cores(), 20);
        assert_eq!(c.occupied_nodes(), 2);
        assert!(c.check_invariants());
    }

    #[test]
    fn best_fit_picks_tightest_single_node() {
        let mut c = Cluster::heterogeneous(&[(16, 0), (4, 0), (8, 0)]);
        // 4-core job: node 1 (slack 0) beats node 2 (slack 4) and 0 (12).
        let a = c.allocate(&job(1, 4), AllocPolicy::BestFit).unwrap();
        assert_eq!(a.taken, vec![(1, 4, 0)]);
    }

    #[test]
    fn best_fit_multi_node_packs_small_holes() {
        let mut c = Cluster::heterogeneous(&[(16, 0), (2, 0), (3, 0)]);
        // Fill node 0 so nothing fits single-node for a 5-core job.
        let filler = c.allocate(&job(9, 16), AllocPolicy::FirstFit).unwrap();
        let a = c.allocate(&job(1, 5), AllocPolicy::BestFit).unwrap();
        // Smallest holes first: node 1 (2 cores) then node 2 (3 cores).
        assert_eq!(a.taken, vec![(1, 2, 0), (2, 3, 0)]);
        c.release(&filler);
        c.release(&a);
        assert_eq!(c.free_cores(), 21);
        assert!(c.check_invariants());
    }

    #[test]
    fn allocate_fails_when_full_and_leaves_state_clean() {
        let mut c = Cluster::homogeneous(1, 4, 0);
        let a = c.allocate(&job(1, 4), AllocPolicy::FirstFit).unwrap();
        assert!(c.allocate(&job(2, 1), AllocPolicy::FirstFit).is_none());
        assert_eq!(c.free_cores(), 0);
        c.release(&a);
        assert_eq!(c.free_cores(), 4);
        assert!(c.check_invariants());
    }

    #[test]
    fn zero_core_job_rejected() {
        let mut c = Cluster::homogeneous(1, 4, 0);
        assert!(c.allocate(&job(1, 0), AllocPolicy::FirstFit).is_none());
    }

    #[test]
    fn memory_constrains_placement() {
        let mut c = Cluster::heterogeneous(&[(8, 100), (8, 4096)]);
        let mut j = job(1, 8);
        j.memory_mb = 2048;
        // Node 0 lacks memory; allocation must land on node 1.
        let a = c.allocate(&j, AllocPolicy::BestFit).unwrap();
        assert_eq!(a.taken.len(), 1);
        assert_eq!(a.taken[0].0, 1);
        assert_eq!(a.taken[0].2, 2048);
        c.release(&a);
        assert_eq!(c.nodes()[1].free_memory_mb, 4096);
    }

    #[test]
    fn feasibility_vs_fits_now() {
        let mut c = Cluster::homogeneous(2, 4, 0);
        let big = job(1, 100);
        assert!(!c.feasible(&big));
        let j = job(2, 8);
        assert!(c.feasible(&j));
        assert!(c.fits_now(&j));
        let _a = c.allocate(&j, AllocPolicy::FirstFit).unwrap();
        assert!(c.feasible(&j));
        assert!(!c.fits_now(&j));
    }

    #[test]
    fn utilization_tracks_allocations() {
        let mut c = Cluster::homogeneous(2, 8, 0);
        assert_eq!(c.utilization(), 0.0);
        let a = c.allocate(&job(1, 8), AllocPolicy::FirstFit).unwrap();
        assert_eq!(c.utilization(), 0.5);
        c.release(&a);
        assert_eq!(c.utilization(), 0.0);
    }

    #[test]
    fn free_vec_matches_nodes() {
        let mut c = Cluster::heterogeneous(&[(4, 0), (8, 0)]);
        let _a = c.allocate(&job(1, 6), AllocPolicy::FirstFit).unwrap();
        assert_eq!(c.free_vec(), vec![0.0, 6.0]);
    }

    #[test]
    fn down_node_leaves_pool_and_returns() {
        let mut c = Cluster::homogeneous(2, 4, 0);
        c.set_node_state(0, NodeState::Down);
        assert_eq!(c.free_cores(), 4);
        assert_eq!(c.available_cores(), 4);
        assert_eq!(c.free_vec(), vec![0.0, 4.0]);
        // Allocation must land entirely on the surviving node.
        let a = c.allocate(&job(1, 4), AllocPolicy::FirstFit).unwrap();
        assert_eq!(a.node_ids(), vec![1]);
        assert!(c.allocate(&job(2, 1), AllocPolicy::FirstFit).is_none());
        c.set_node_state(0, NodeState::Up);
        assert_eq!(c.free_cores(), 4);
        assert_eq!(c.available_cores(), 8);
        c.release(&a);
        assert_eq!(c.free_cores(), 8);
        assert!(c.check_invariants());
    }

    #[test]
    fn draining_and_reserved_reject_new_work() {
        for s in [NodeState::Draining, NodeState::Reserved] {
            let mut c = Cluster::homogeneous(1, 4, 0);
            c.set_node_state(0, s);
            assert!(c.allocate(&job(1, 1), AllocPolicy::FirstFit).is_none());
            assert!(c.allocate(&job(1, 1), AllocPolicy::BestFit).is_none());
            assert_eq!(c.free_cores(), 0);
            assert_eq!(c.available_cores(), 4, "{s:?} capacity is not failed");
            assert!(c.check_invariants());
        }
    }

    #[test]
    fn release_onto_down_node_stays_out_of_pool() {
        let mut c = Cluster::homogeneous(2, 4, 0);
        let a = c.allocate(&job(1, 4), AllocPolicy::FirstFit).unwrap();
        assert_eq!(a.node_ids(), vec![0]);
        c.set_node_state(0, NodeState::Down);
        // The occupant is killed by the driver; its cores return to the
        // node but not to the schedulable pool.
        c.release(&a);
        assert_eq!(c.free_cores(), 4);
        assert_eq!(c.busy_cores(), 0);
        assert!(c.check_invariants());
        c.set_node_state(0, NodeState::Up);
        assert_eq!(c.free_cores(), 8);
        assert!(c.check_invariants());
    }

    #[test]
    fn effective_utilization_excludes_down_capacity() {
        let mut c = Cluster::homogeneous(4, 4, 0);
        let _a = c.allocate(&job(1, 4), AllocPolicy::FirstFit).unwrap();
        assert_eq!(c.utilization(), 0.25);
        assert_eq!(c.effective_utilization(), 0.25);
        c.set_node_state(3, NodeState::Down);
        assert_eq!(c.utilization(), 0.25);
        assert!((c.effective_utilization() - 4.0 / 12.0).abs() < 1e-12);
        assert_eq!(c.nodes_in_state(NodeState::Down).collect::<Vec<_>>(), vec![3]);
    }

    #[test]
    fn reservation_plan_cores_uses_largest_capacities() {
        let c = Cluster::heterogeneous(&[(4, 0), (16, 0), (8, 0)]);
        assert_eq!(c.reservation_plan_cores(1), 16);
        assert_eq!(c.reservation_plan_cores(2), 24);
        assert_eq!(c.reservation_plan_cores(3), 28);
        assert_eq!(c.reservation_plan_cores(99), 28);
    }

    #[test]
    fn memory_pools_track_allocations() {
        let mut c = Cluster::heterogeneous(&[(8, 1000), (8, 500)]);
        assert_eq!(c.total_memory_mb(), 1500);
        assert_eq!(c.free_memory_mb(), 1500);
        assert_eq!(c.reservation_plan_mem(1), 1000);
        assert_eq!(c.reservation_plan_mem(9), 1500);
        let mut j = job(1, 8);
        j.memory_mb = 800;
        let a = c.allocate(&j, AllocPolicy::FirstFit).unwrap();
        assert_eq!(a.memory_mb(), 800);
        assert_eq!(a.demand(), ResourceVector::new(8, 800));
        assert_eq!(c.busy_memory_mb(), 800);
        assert_eq!(c.free_memory_mb(), 700);
        assert!((c.memory_utilization() - 800.0 / 1500.0).abs() < 1e-12);
        // A non-Up node's free memory leaves the schedulable pool.
        c.set_node_state(1, NodeState::Down);
        assert_eq!(c.free_memory_mb(), 200);
        c.set_node_state(1, NodeState::Up);
        c.release(&a);
        assert_eq!(c.free_memory_mb(), 1500);
        assert_eq!(c.memory_utilization(), 0.0);
    }
}
