//! Multi-resource demands: the vector the planning layer plans in.
//!
//! The paper's resource-management component tracks both processors and
//! memory per node; [`ResourceVector`] is the aggregate demand a job (or
//! an allocation, or a reservation) places on the machine — one value
//! per tracked dimension, compared and combined component-wise. It is
//! deliberately a plain-old-data struct: adding a dimension (GPUs,
//! burst-buffer slots, ...) means adding a field here and a lazily
//! materialized timeline in `profile` — nothing in the scheduler seam
//! changes shape.

/// Aggregate multi-resource demand: cores plus memory (MB).
///
/// `memory_mb == 0` means "no memory demand" everywhere in the planning
/// layer; a profile that does not track memory ignores the field
/// entirely, which is what keeps cores-only workloads bit-identical to
/// the scalar planner this type generalizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct ResourceVector {
    pub cores: u64,
    pub memory_mb: u64,
}

impl ResourceVector {
    pub const ZERO: ResourceVector = ResourceVector { cores: 0, memory_mb: 0 };

    pub fn new(cores: u64, memory_mb: u64) -> ResourceVector {
        ResourceVector { cores, memory_mb }
    }

    /// A demand with no memory component (the scalar-planner shape).
    pub fn cores_only(cores: u64) -> ResourceVector {
        ResourceVector { cores, memory_mb: 0 }
    }

    /// Component-wise `<=`: whether this demand fits inside `avail`.
    pub fn fits(self, avail: ResourceVector) -> bool {
        self.cores <= avail.cores && self.memory_mb <= avail.memory_mb
    }

    /// Component-wise sum.
    pub fn add(self, other: ResourceVector) -> ResourceVector {
        ResourceVector {
            cores: self.cores + other.cores,
            memory_mb: self.memory_mb + other.memory_mb,
        }
    }

    /// Component-wise difference; panics (debug) on underflow — use
    /// [`ResourceVector::saturating_sub`] when the argument may exceed.
    pub fn sub(self, other: ResourceVector) -> ResourceVector {
        debug_assert!(other.fits(self), "ResourceVector underflow: {self:?} - {other:?}");
        ResourceVector {
            cores: self.cores - other.cores,
            memory_mb: self.memory_mb - other.memory_mb,
        }
    }

    /// Component-wise saturating difference.
    pub fn saturating_sub(self, other: ResourceVector) -> ResourceVector {
        ResourceVector {
            cores: self.cores.saturating_sub(other.cores),
            memory_mb: self.memory_mb.saturating_sub(other.memory_mb),
        }
    }

    pub fn is_zero(self) -> bool {
        self == ResourceVector::ZERO
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_is_component_wise() {
        let avail = ResourceVector::new(8, 1024);
        assert!(ResourceVector::new(8, 1024).fits(avail));
        assert!(ResourceVector::new(0, 0).fits(avail));
        assert!(!ResourceVector::new(9, 0).fits(avail), "cores alone can fail");
        assert!(!ResourceVector::new(0, 2048).fits(avail), "memory alone can fail");
    }

    #[test]
    fn add_sub_roundtrip() {
        let a = ResourceVector::new(4, 512);
        let b = ResourceVector::new(2, 128);
        assert_eq!(a.add(b).sub(b), a);
        assert_eq!(b.saturating_sub(a), ResourceVector::ZERO);
        assert!(ResourceVector::ZERO.is_zero());
        assert!(!ResourceVector::cores_only(1).is_zero());
    }
}
