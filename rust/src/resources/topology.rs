//! Network topology modeling (SST's Merlin analogue).
//!
//! The paper leans on SST's Merlin element for "diverse network
//! topologies such as dragonfly, torus, mesh, and fattree". This module
//! provides that substrate at the granularity the job simulator needs:
//! node-to-node hop distances per topology, locality-aware allocation
//! scoring, and a communication-slowdown model that stretches job
//! runtimes when their allocation is fragmented across the machine.

use crate::resources::Allocation;

/// Supported interconnect topologies (the four Merlin examples the paper
/// names).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Topology {
    /// 2-D mesh of given dimensions (no wraparound).
    Mesh2D { x: usize, y: usize },
    /// 2-D torus (wraparound links).
    Torus2D { x: usize, y: usize },
    /// k-ary fat tree: `leaf` nodes per edge switch, `agg` edge switches
    /// per pod. Distance = 1 within a switch, 3 within a pod, 5 across.
    FatTree { leaf: usize, agg: usize },
    /// Dragonfly: `a` routers per group, `p` nodes per router. Distance =
    /// 1 same router, 2 same group, 3 global (one global hop, canonical
    /// minimal routing).
    Dragonfly { a: usize, p: usize },
}

impl Topology {
    /// Number of compute nodes the topology wires.
    pub fn nodes(&self) -> usize {
        match *self {
            Topology::Mesh2D { x, y } | Topology::Torus2D { x, y } => x * y,
            Topology::FatTree { leaf, agg } => leaf * agg * agg,
            Topology::Dragonfly { a, p } => a * p * (a + 1), // a+1 groups (balanced)
        }
    }

    /// Hop distance between node ids (0-based, < nodes()).
    pub fn distance(&self, u: usize, v: usize) -> usize {
        if u == v {
            return 0;
        }
        match *self {
            Topology::Mesh2D { x, .. } => {
                let (ux, uy) = (u % x, u / x);
                let (vx, vy) = (v % x, v / x);
                ux.abs_diff(vx) + uy.abs_diff(vy)
            }
            Topology::Torus2D { x, y } => {
                let (ux, uy) = (u % x, u / x);
                let (vx, vy) = (v % x, v / x);
                let dx = ux.abs_diff(vx).min(x - ux.abs_diff(vx));
                let dy = uy.abs_diff(vy).min(y - uy.abs_diff(vy));
                dx + dy
            }
            Topology::FatTree { leaf, agg } => {
                let (us, vs) = (u / leaf, v / leaf); // edge switch
                if us == vs {
                    return 1;
                }
                let pod = agg; // `agg` edge switches per pod
                if us / pod == vs / pod {
                    3
                } else {
                    5
                }
            }
            Topology::Dragonfly { a, p } => {
                let (ur, vr) = (u / p, v / p); // router
                if ur == vr {
                    return 1;
                }
                if ur / a == vr / a {
                    2 // same group
                } else {
                    3 // minimal global route
                }
            }
        }
    }

    /// Mean pairwise hop distance of an allocation's node set — the
    /// locality score a topology-aware allocator minimizes.
    pub fn allocation_span(&self, nodes: &[usize]) -> f64 {
        if nodes.len() < 2 {
            return 0.0;
        }
        let mut total = 0usize;
        let mut pairs = 0usize;
        for i in 0..nodes.len() {
            for j in i + 1..nodes.len() {
                total += self.distance(nodes[i], nodes[j]);
                pairs += 1;
            }
        }
        total as f64 / pairs as f64
    }

    /// Communication slowdown factor for a job on this allocation:
    /// 1 + sensitivity * (mean hops - 1)+ . `sensitivity` models how
    /// communication-bound the application is (0 = embarrassingly
    /// parallel).
    pub fn slowdown(&self, alloc: &Allocation, sensitivity: f64) -> f64 {
        let span = self.allocation_span(&alloc.node_ids());
        1.0 + sensitivity * (span - 1.0).max(0.0)
    }

    /// Diameter (max distance over sampled pairs; exact for these closed
    /// forms).
    pub fn diameter(&self) -> usize {
        match *self {
            Topology::Mesh2D { x, y } => (x - 1) + (y - 1),
            Topology::Torus2D { x, y } => x / 2 + y / 2,
            Topology::FatTree { agg, .. } => {
                if agg > 1 {
                    5
                } else {
                    3
                }
            }
            Topology::Dragonfly { .. } => 3,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_distances() {
        let t = Topology::Mesh2D { x: 4, y: 4 };
        assert_eq!(t.nodes(), 16);
        assert_eq!(t.distance(0, 0), 0);
        assert_eq!(t.distance(0, 3), 3); // same row
        assert_eq!(t.distance(0, 15), 6); // opposite corner
        assert_eq!(t.diameter(), 6);
    }

    #[test]
    fn torus_wraps() {
        let t = Topology::Torus2D { x: 4, y: 4 };
        assert_eq!(t.distance(0, 3), 1); // wraparound beats 3 hops
        assert_eq!(t.distance(0, 15), 2); // (-1, -1)
        assert_eq!(t.diameter(), 4);
        // Torus never exceeds mesh distance.
        let m = Topology::Mesh2D { x: 4, y: 4 };
        for u in 0..16 {
            for v in 0..16 {
                assert!(t.distance(u, v) <= m.distance(u, v));
            }
        }
    }

    #[test]
    fn fat_tree_tiers() {
        let t = Topology::FatTree { leaf: 4, agg: 2 };
        assert_eq!(t.nodes(), 16);
        assert_eq!(t.distance(0, 1), 1); // same edge switch
        assert_eq!(t.distance(0, 4), 3); // same pod, different switch
        assert_eq!(t.distance(0, 8), 5); // cross pod
    }

    #[test]
    fn dragonfly_tiers() {
        let t = Topology::Dragonfly { a: 4, p: 2 };
        assert_eq!(t.nodes(), 4 * 2 * 5);
        assert_eq!(t.distance(0, 1), 1); // same router
        assert_eq!(t.distance(0, 2), 2); // same group
        assert_eq!(t.distance(0, 8), 3); // other group
    }

    #[test]
    fn distances_are_symmetric_metrics() {
        for t in [
            Topology::Mesh2D { x: 5, y: 3 },
            Topology::Torus2D { x: 5, y: 3 },
            Topology::FatTree { leaf: 3, agg: 2 },
            Topology::Dragonfly { a: 3, p: 2 },
        ] {
            let n = t.nodes();
            for u in 0..n {
                assert_eq!(t.distance(u, u), 0);
                for v in 0..n {
                    assert_eq!(t.distance(u, v), t.distance(v, u), "{t:?} {u} {v}");
                    assert!(t.distance(u, v) <= t.diameter(), "{t:?} {u}->{v}");
                }
            }
        }
    }

    #[test]
    fn allocation_span_and_slowdown() {
        let t = Topology::Mesh2D { x: 8, y: 1 };
        let tight = Allocation { job_id: 1, taken: vec![(0, 1, 0), (1, 1, 0)] };
        let spread = Allocation { job_id: 2, taken: vec![(0, 1, 0), (7, 1, 0)] };
        assert_eq!(t.allocation_span(&tight.node_ids()), 1.0);
        assert_eq!(t.allocation_span(&spread.node_ids()), 7.0);
        assert_eq!(t.slowdown(&tight, 0.1), 1.0);
        assert!((t.slowdown(&spread, 0.1) - 1.6).abs() < 1e-12);
        // Insensitive apps never slow down.
        assert_eq!(t.slowdown(&spread, 0.0), 1.0);
    }

    #[test]
    fn single_node_alloc_has_zero_span() {
        let t = Topology::Dragonfly { a: 4, p: 4 };
        let a = Allocation { job_id: 1, taken: vec![(3, 4, 0)] };
        assert_eq!(t.allocation_span(&a.node_ids()), 0.0);
        assert_eq!(t.slowdown(&a, 1.0), 1.0);
    }
}
