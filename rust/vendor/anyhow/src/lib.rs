//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The repository builds without network access, so instead of the real
//! crate this vendored shim provides exactly the surface `sst_sched`
//! uses: [`Error`], [`Result`], the [`Context`] extension trait for
//! `Result`/`Option`, and the [`anyhow!`]/[`bail!`] macros. Context is
//! joined eagerly into the message (`"context: cause"`), so both `{}`
//! and `{:#}` display the full chain — the crate only ever formats
//! errors for terminal output and substring assertions.

use std::fmt;

/// A string-backed error value.
///
/// Like `anyhow::Error`, this deliberately does **not** implement
/// `std::error::Error`, which is what makes the blanket
/// `From<E: std::error::Error>` conversion below coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer: `"context: cause"`.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(&$err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_messages() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        let text = format!("{e:#}");
        assert!(text.contains("reading file"), "{text}");
        assert!(text.contains("gone"), "{text}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
        assert_eq!(Some(5u32).context("ok").unwrap(), 5);
    }

    #[test]
    fn with_context_is_lazy() {
        let mut called = false;
        let _ = Ok::<_, Error>(1).with_context(|| {
            called = true;
            "never"
        });
        assert!(!called);
    }

    #[test]
    fn macros_build_errors() {
        fn fails(x: u32) -> Result<()> {
            if x > 1 {
                bail!("x too big: {x}");
            }
            Err(anyhow!("fallthrough"))
        }
        assert!(fails(5).unwrap_err().to_string().contains("x too big: 5"));
        assert_eq!(fails(0).unwrap_err().to_string(), "fallthrough");
        let owned = String::from("owned message");
        let e = anyhow!(owned);
        assert_eq!(e.to_string(), "owned message");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here/xyz")?;
            Ok(s)
        }
        assert!(read().is_err());
    }
}
