//! Crash-fault chaos harness for the journaled serve daemon.
//!
//! The property under test is the whole point of the write-ahead
//! journal: for ANY crash point — including torn journal tails — and
//! any durability mode, recovery rebuilds a daemon whose per-sim
//! fingerprints are byte-identical to an uncrashed reference that
//! processed exactly the requests the journal preserved. The
//! determinism contract (`tests/snapshot.rs`) is what makes this an
//! equality assertion rather than a tolerance.
//!
//! The harness drives a [`ServerCore`] directly (no socket): scripted
//! submit bursts, a crash simulated by [`ServerCore::crash`] (which
//! drops the journal without the graceful flush) at a randomized
//! request boundary, optionally an artificially truncated journal tail
//! on top, then [`recover`] and compare.

use sst_sched::config::{Durability, ExperimentConfig};
use sst_sched::core::rng::Rng;
use sst_sched::runtime::journal::{self, Journal};
use sst_sched::runtime::recover;
use sst_sched::runtime::serve::ServerCore;
use sst_sched::sched::Policy;
use sst_sched::sim::Simulation;
use sst_sched::trace::Workload;
use sst_sched::util::prop::check_n;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

fn temp_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("sst-crashrec-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Small machine, journaling into `dir`, aggressive mark cadence so
/// compaction happens inside short scripts.
fn test_cfg(dir: &Path, durability: Durability, mark_interval: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        nodes: Some(2),
        cores_per_node: Some(4),
        policy: Policy::Fcfs,
        ..ExperimentConfig::default()
    };
    cfg.serve.state_dir = Some(dir.to_str().unwrap().to_string());
    cfg.serve.durability = durability;
    cfg.serve.mark_interval = mark_interval;
    cfg
}

fn journaled_core(cfg: &ExperimentConfig, dir: &Path) -> ServerCore {
    let mut core = ServerCore::new(cfg.clone());
    core.attach_journal(
        Journal::create(dir, cfg.semantic_hash(), cfg.serve.durability).unwrap(),
    );
    core
}

/// Scripted submit burst over sims "a"/"b" with a globally non-
/// decreasing arrival clock, so every request succeeds (arrivals can
/// never regress a sim's clock). Returns the lines and the final tick.
fn gen_script(rng: &mut Rng, n: usize) -> (Vec<String>, u64) {
    let mut t = 0u64;
    let mut lines = Vec::with_capacity(n);
    for _ in 0..n {
        t += rng.below(50);
        let sim = if rng.below(2) == 0 { "a" } else { "b" };
        let cores = 1 + rng.below(4);
        let runtime = 1 + rng.below(500);
        lines.push(format!(
            r#"{{"req":"submit","sim":"{sim}","at":{t},"job":{{"cores":{cores},"runtime":{runtime}}}}}"#
        ));
    }
    (lines, t)
}

fn feed(core: &mut ServerCore, lines: &[String]) -> Result<(), String> {
    for (i, l) in lines.iter().enumerate() {
        let r = core.handle_line(i as u64 + 1, l);
        if !r.get_bool_or("ok", false) {
            return Err(format!("submit refused: {r:?} for {l}"));
        }
    }
    Ok(())
}

/// Uncrashed reference: a fresh in-memory core fed exactly `lines`.
fn reference_core(cfg: &ExperimentConfig, lines: &[String]) -> ServerCore {
    let mut c = ServerCore::new(cfg.clone());
    feed(&mut c, lines).expect("reference submits must succeed");
    c
}

/// Per-sim future fingerprints — the byte-identity the chaos property
/// asserts.
fn fingerprints(core: &ServerCore) -> BTreeMap<String, String> {
    core.sim_names()
        .into_iter()
        .map(|n| {
            let fp = core.fingerprint(&n).expect("hosted sims fingerprint");
            (n, fp)
        })
        .collect()
}

/// Chop `cut` bytes off the journal's end — the torn tail a crash
/// mid-append leaves.
fn truncate_journal(dir: &Path, cut: u64) {
    let jpath = dir.join(journal::FILE_NAME);
    let len = std::fs::metadata(&jpath).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&jpath).unwrap();
    f.set_len(len - cut).unwrap();
}

/// The acceptance-criteria chaos property: randomized crash points
/// (including torn tails) across seeds and all three durability modes;
/// the recovered daemon must be byte-identical to a reference run over
/// the journal's surviving prefix, and must keep working (submit more,
/// crash-free second recovery) afterwards.
#[test]
fn chaos_random_crash_points_recover_byte_identical() {
    let modes = [Durability::Strict, Durability::Batched, Durability::Off];
    let mut case = 0usize;
    check_n("crash-recovery-chaos", 12, |rng| {
        let mode = modes[case % modes.len()];
        case += 1;
        let dir = temp_dir(&format!("chaos{case}"));
        let cfg = test_cfg(&dir, mode, 4);
        let mut core = journaled_core(&cfg, &dir);

        let n = 5 + rng.below(20) as usize;
        let (lines, t_end) = gen_script(rng, n);
        let crash_at = rng.below(n as u64 + 1) as usize;
        feed(&mut core, &lines[..crash_at])?;
        core.crash();

        // Half the cases additionally tear the tail mid-record.
        let jpath = dir.join(journal::FILE_NAME);
        let len = std::fs::metadata(&jpath).map_err(|e| e.to_string())?.len();
        let hdr = journal::HEADER_BYTES as u64;
        let torn = rng.below(2) == 1 && len > hdr;
        if torn {
            truncate_journal(&dir, 1 + rng.below((len - hdr).min(40)));
        }

        let (rcore, report) =
            recover::recover(&cfg, &dir).map_err(|e| format!("recovery failed: {e:#}"))?;
        // The journal preserves a prefix of the submit stream: the jobs
        // checkpointed by the latest MARK plus the replayed suffix.
        let k = report.marked_jobs + report.replayed_submits;
        if k > crash_at {
            return Err(format!("recovered {k} submits but only {crash_at} were issued"));
        }
        if mode != Durability::Off && !torn && k != crash_at {
            return Err(format!(
                "a {mode} journal must survive a process crash intact: \
                 recovered {k} of {crash_at}"
            ));
        }
        let reference = reference_core(&cfg, &lines[..k]);
        if fingerprints(&rcore) != fingerprints(&reference) {
            return Err(format!(
                "recovered fingerprints diverge from the reference \
                 (mode {mode}, crash at {crash_at}, torn {torn}, surviving {k})"
            ));
        }

        // The recovered daemon is live: journal reattached, new submits
        // land, and a graceful close + second recovery still matches.
        let mut rcore = rcore;
        if !rcore.journal_active() {
            return Err("recovery must reattach the journal".to_string());
        }
        let more =
            format!(r#"{{"req":"submit","sim":"a","at":{},"job":{{"cores":1,"runtime":9}}}}"#, t_end + 1);
        let r = rcore.handle_line(1, &more);
        if !r.get_bool_or("ok", false) {
            return Err(format!("post-recovery submit refused: {r:?}"));
        }
        drop(rcore); // graceful: flushes even in `off` mode
        let (again, _) =
            recover::recover(&cfg, &dir).map_err(|e| format!("second recovery: {e:#}"))?;
        let mut extended = reference;
        feed(&mut extended, std::slice::from_ref(&more))?;
        if fingerprints(&again) != fingerprints(&extended) {
            return Err("second recovery diverged from the extended reference".to_string());
        }

        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
}

/// Compaction contract: once `mark_interval` submits are journaled the
/// file is rewritten as header + MARK, and recovery replays from the
/// mark's step bound — not from t=0.
#[test]
fn recovery_after_compaction_replays_from_the_mark() {
    let dir = temp_dir("compact");
    let cfg = test_cfg(&dir, Durability::Batched, 4);
    let mut core = journaled_core(&cfg, &dir);
    let lines: Vec<String> = (0..10)
        .map(|i| {
            format!(r#"{{"req":"submit","at":{},"job":{{"cores":1,"runtime":50}}}}"#, i * 10)
        })
        .collect();
    feed(&mut core, &lines).unwrap();
    let live = fingerprints(&core);
    drop(core);

    // On disk: marks at submits 4 and 8 compacted everything before
    // them, so the file is exactly MARK + the 2-submit suffix.
    let img = journal::read_file(&dir.join(journal::FILE_NAME)).unwrap();
    assert!(
        matches!(img.records.first(), Some(journal::Record::Mark(_))),
        "compaction must leave the mark first"
    );
    assert_eq!(img.records.len(), 3, "mark + 2 replay submits, not all 10");

    let (rcore, report) = recover::recover(&cfg, &dir).unwrap();
    assert!(report.from_mark, "replay must start from the MARK");
    assert_eq!(report.marked_jobs, 8);
    assert_eq!(report.replayed_submits, 2);
    assert!(report.mark_step_bound > 0, "mark records the step bound replay starts from");
    assert_eq!(report.verified_sims, 1, "the mark's fingerprint digest is asserted");
    assert_eq!(fingerprints(&rcore), live);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A clean `shutdown` journals a SHUTDOWN record and flushes; resuming
/// starts a fresh serve lifetime (not draining) with every sim intact.
#[test]
fn clean_shutdown_then_resume() {
    let dir = temp_dir("shutdown");
    let cfg = test_cfg(&dir, Durability::Off, 64);
    let mut core = journaled_core(&cfg, &dir);
    let (lines, t_end) = gen_script(&mut Rng::new(42), 6);
    feed(&mut core, &lines).unwrap();
    let live = fingerprints(&core);
    let r = core.handle_line(7, r#"{"req":"shutdown"}"#);
    assert!(r.get_bool_or("draining", false));
    drop(core);

    let (mut rcore, report) = recover::recover(&cfg, &dir).unwrap();
    assert_eq!(report.shutdowns, 1, "the clean close is visible in the report");
    assert!(!rcore.draining(), "a resumed daemon starts un-drained");
    assert_eq!(fingerprints(&rcore), live);
    let more = format!(
        r#"{{"req":"submit","sim":"a","at":{},"job":{{"cores":1,"runtime":5}}}}"#,
        t_end + 1
    );
    assert!(rcore.handle_line(1, &more).get_bool_or("ok", false));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Torn tails (deterministic shape): the intact prefix is recovered,
/// the tear is reported, and the reattached journal is truncated clean.
#[test]
fn torn_tail_is_discarded_and_reported() {
    let dir = temp_dir("torn");
    let cfg = test_cfg(&dir, Durability::Strict, 64);
    let mut core = journaled_core(&cfg, &dir);
    let (lines, _) = gen_script(&mut Rng::new(7), 3);
    feed(&mut core, &lines).unwrap();
    core.crash();
    truncate_journal(&dir, 5); // into record 2's payload

    let (rcore, report) = recover::recover(&cfg, &dir).unwrap();
    assert!(report.torn_tail.is_some(), "the tear must be reported");
    assert_eq!(report.marked_jobs + report.replayed_submits, 2);
    assert_eq!(fingerprints(&rcore), fingerprints(&reference_core(&cfg, &lines[..2])));
    drop(rcore);
    // Reattaching truncated the tail away: the file re-reads clean.
    let img = journal::read_file(&dir.join(journal::FILE_NAME)).unwrap();
    assert!(img.torn.is_none(), "recovery must leave a clean journal behind");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A journal only resumes under the config that wrote it — but "config"
/// means simulation semantics: serve plumbing (socket, durability...)
/// may differ freely.
#[test]
fn config_mismatch_is_refused_plumbing_changes_are_not() {
    let dir = temp_dir("cfg");
    let cfg = test_cfg(&dir, Durability::Strict, 64);
    let mut core = journaled_core(&cfg, &dir);
    let (lines, _) = gen_script(&mut Rng::new(3), 4);
    feed(&mut core, &lines).unwrap();
    drop(core);

    let mut other = cfg.clone();
    other.seed += 1;
    let err = format!("{:#}", recover::recover(&other, &dir).unwrap_err());
    assert!(err.contains("different experiment config"), "{err}");

    let mut plumbing = cfg.clone();
    plumbing.serve.socket = "/tmp/somewhere-else.sock".to_string();
    plumbing.serve.durability = Durability::Off;
    plumbing.serve.queue_depth = 7;
    let (rcore, _) = recover::recover(&plumbing, &dir).unwrap();
    assert_eq!(fingerprints(&rcore), fingerprints(&reference_core(&cfg, &lines)));
    drop(rcore);

    let empty = temp_dir("cfg-empty");
    std::fs::create_dir_all(&empty).unwrap();
    let err = format!("{:#}", recover::recover(&cfg, &empty).unwrap_err());
    assert!(err.contains("nothing to resume"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&empty);
}

/// Mid-file corruption (a flipped byte in a *complete* record) must
/// refuse recovery with the record index and byte offset — never
/// silently replay scrambled state.
#[test]
fn mid_file_corruption_refuses_recovery_with_diagnostics() {
    let dir = temp_dir("corrupt");
    let cfg = test_cfg(&dir, Durability::Strict, 64);
    let mut core = journaled_core(&cfg, &dir);
    let (lines, _) = gen_script(&mut Rng::new(11), 3);
    feed(&mut core, &lines).unwrap();
    drop(core);

    let jpath = dir.join(journal::FILE_NAME);
    let mut bytes = std::fs::read(&jpath).unwrap();
    let off = journal::HEADER_BYTES + journal::RECORD_HEADER_BYTES;
    bytes[off] ^= 0x01; // flip one payload byte of record 0
    std::fs::write(&jpath, &bytes).unwrap();

    let err = format!("{:#}", recover::recover(&cfg, &dir).unwrap_err());
    assert!(err.contains("record 0"), "{err}");
    assert!(err.contains("checksum"), "{err}");
    assert!(err.contains("corrupt mid-file"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Streamed (`with_job_stream`) sims cannot be snapshotted, so they
/// cannot be journaled either: the mark path reports the snapshot
/// layer's clear by-name error instead of half-journaling.
#[test]
fn streamed_sims_are_rejected_from_journaled_serve() {
    use sst_sched::trace::{JobStream, TraceFormat};
    let swf = "1 0 -1 10 1 -1 -1 1 10 -1 1 1 1 1 -1 -1 -1 -1\n";
    let stream =
        JobStream::new(std::io::Cursor::new(swf.as_bytes().to_vec()), TraceFormat::Swf);
    let inst = Simulation::new(Workload::machine("streamed", 2, 4), Policy::Fcfs)
        .with_job_stream(Box::new(stream.map(|j| j.unwrap())))
        .build();
    let err = journal::mark_fingerprint(&inst)
        .expect_err("streamed sims must not be journalable");
    assert!(err.contains("source"), "error should name the component: {err}");
}
