//! Runtime simulation-sanitizer integration tests.
//!
//! Three layers of proof that the determinism sentinel's runtime half
//! actually works:
//!
//! * a full SP2 faults + reservations + preemption + backfill scenario
//!   runs end-to-end with every sanitizer invariant exercised at least
//!   once (asserted via the global check counters);
//! * a threaded sharded federation run exercises the YAWNS delivery
//!   bound checker;
//! * a corruption hook proves the profile-vs-rebuild oracle really
//!   trips when the incremental timeline is skewed.
//!
//! All counter assertions are gated on `sanitizer::ACTIVE` so this file
//! also compiles and passes in an ordinary release build (where the
//! checks fold away); CI runs it with `--features sanitize`.

use sst_sched::analysis::sanitizer;
use sst_sched::core::time::SimDuration;
use sst_sched::parallel::{run_sharded, RankSimOpts, ShardOpts};
use sst_sched::sched::{Policy, PreemptionConfig, PreemptionMode};
use sst_sched::sim::{
    FaultConfig, MetaScheduler, ReservationSpec, Routing, Simulation,
};
use sst_sched::trace::{Das2Model, SdscSp2Model};

/// The SP2 golden-scenario composition: synthetic SDSC SP2 workload
/// under failures, advance reservations, checkpoint preemption and
/// FCFS+backfill.
fn sp2_fault_scenario(n_jobs: usize, seed: u64) -> sst_sched::sim::SimInstance {
    let w = SdscSp2Model::default().generate(n_jobs, seed);
    let nodes = w.nodes;
    let reservations = vec![
        ReservationSpec { start: 5_000, duration: 20_000, nodes: (nodes / 8).max(1) },
        ReservationSpec { start: 40_000, duration: 10_000, nodes: (nodes / 16).max(1) },
    ];
    Simulation::new(w, Policy::FcfsBackfill)
        .with_seed(seed ^ 0x5eed)
        .with_faults(FaultConfig {
            mtbf: 20_000.0,
            mttr: 4_000.0,
            seed: seed.wrapping_mul(77),
            ..FaultConfig::default()
        })
        .with_preemption(PreemptionConfig {
            mode: PreemptionMode::Checkpoint,
            checkpoint_overhead: SimDuration(30),
            restart_overhead: SimDuration(30),
            starvation_threshold: SimDuration(2_000),
        })
        .with_reservations(reservations)
        .build()
}

#[test]
fn sp2_fault_scenario_passes_sanitizer_with_every_invariant_checked() {
    let before = sanitizer::stats();
    let mut inst = sp2_fault_scenario(300, 42);
    inst.engine.run(None);
    if !sanitizer::ACTIVE {
        return; // plain release: checks fold away, nothing to count
    }
    let after = sanitizer::stats();
    // Every invariant family ran at least once during the scenario.
    assert!(
        after.conservation > before.conservation,
        "conservation law never checked"
    );
    assert!(
        after.profile > before.profile,
        "profile-vs-rebuild oracle never ran"
    );
    assert!(
        after.segment > before.segment,
        "segment accounting never checked"
    );
    assert!(after.pops > before.pops, "pop-order monotonicity never checked");
    assert!(
        after.engine_time > before.engine_time,
        "engine time-monotonicity never checked"
    );
}

#[test]
fn sanitizer_survives_a_seed_sweep_of_fault_scenarios() {
    // Property-flavored: the composed scenario completes under the
    // sanitizer for several seeds (any invariant violation panics).
    for seed in [1u64, 7, 1234] {
        let mut inst = sp2_fault_scenario(150, seed);
        let report = inst.engine.run(None);
        assert!(report.events > 0, "seed {seed}: no events processed");
    }
}

#[test]
fn sharded_federation_run_exercises_the_delivery_bound_check() {
    let before = sanitizer::stats();
    let routing = Routing::RoundRobin;
    let opts = ShardOpts {
        clusters: MetaScheduler::das2_federation(routing, Policy::FcfsBackfill).clusters,
        routing,
        policy: Policy::FcfsBackfill,
        shards: 2,
        route_latency: 60,
        sim: RankSimOpts::default(),
    };
    let jobs = Das2Model::default().generate(800, 9).scale_arrivals(0.3).jobs;
    let report = run_sharded(&opts, jobs, true);
    assert!(report.total_completed() > 0);
    if !sanitizer::ACTIVE {
        return;
    }
    let after = sanitizer::stats();
    assert!(
        after.delivery > before.delivery,
        "cross-shard delivery bound never checked"
    );
}

#[cfg(any(debug_assertions, feature = "sanitize"))]
mod corruption {
    //! Prove the invariants trip: corrupt live state through the
    //! test-only hooks and watch the sanitizer panic.

    use std::panic::{catch_unwind, AssertUnwindSafe};

    use sst_sched::sched::Policy;
    use sst_sched::sim::{SchedulerComponent, Simulation};
    use sst_sched::trace::SdscSp2Model;

    #[test]
    fn profile_oracle_trips_on_a_skewed_timeline() {
        // Fault-free run so the end state is pristine and the profile
        // oracle has an exact ground truth.
        let w = SdscSp2Model::default().generate(80, 5);
        let mut inst = Simulation::new(w, Policy::FcfsBackfill).with_seed(11).build();
        inst.engine.run(None);
        let now = inst.engine.now().ticks();
        let id = inst.engine.id_of("scheduler").expect("scheduler registered");
        let s = inst.engine.get_mut::<SchedulerComponent>(id).expect("downcast");

        // Positive control: the clean end state verifies.
        s.sanitizer_verify_profile_for_test(now);

        // Skew the incremental timeline by one phantom held core; the
        // rebuild oracle must now disagree and panic.
        s.sanitizer_skew_hold_for_test(now);
        let tripped =
            catch_unwind(AssertUnwindSafe(|| s.sanitizer_verify_profile_for_test(now)))
                .is_err();
        assert!(tripped, "profile oracle accepted a corrupted timeline");
    }
}
