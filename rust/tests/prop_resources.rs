//! Property tests: resource-manager invariants under random
//! allocate/release sequences (paper Algorithm 1's conservation laws).

use sst_sched::core::rng::Rng;
use sst_sched::job::Job;
use sst_sched::resources::{AllocPolicy, Allocation, Cluster};
use sst_sched::util::prop::check;

fn random_cluster(rng: &mut Rng) -> Cluster {
    if rng.chance(0.5) {
        Cluster::homogeneous(rng.range(1, 32) as usize, rng.range(1, 16), 0)
    } else {
        let n = rng.range(1, 24) as usize;
        let specs: Vec<(u64, u64)> =
            (0..n).map(|_| (rng.range(1, 32), rng.range(0, 8192))).collect();
        Cluster::heterogeneous(&specs)
    }
}

fn policy(rng: &mut Rng) -> AllocPolicy {
    if rng.chance(0.5) {
        AllocPolicy::FirstFit
    } else {
        AllocPolicy::BestFit
    }
}

#[test]
fn conservation_under_random_traffic() {
    check("conservation", |rng| {
        let mut c = random_cluster(rng);
        let total = c.total_cores();
        let mut live: Vec<Allocation> = Vec::new();
        for step in 0..100u64 {
            if rng.chance(0.6) || live.is_empty() {
                let job = Job::simple(step, 0, rng.range(1, total + 4), 10);
                if let Some(a) = c.allocate(&job, policy(rng)) {
                    if a.cores() != job.cores {
                        return Err(format!("allocated {} != requested {}", a.cores(), job.cores));
                    }
                    live.push(a);
                }
            } else {
                let idx = rng.below(live.len() as u64) as usize;
                let a = live.swap_remove(idx);
                c.release(&a);
            }
            if !c.check_invariants() {
                return Err(format!("invariants broken at step {step}"));
            }
            let held: u64 = live.iter().map(|a| a.cores()).sum();
            if c.free_cores() + held != total {
                return Err(format!(
                    "leak: free {} + held {held} != total {total}",
                    c.free_cores()
                ));
            }
        }
        // Release everything: cluster must be pristine.
        for a in live.drain(..) {
            c.release(&a);
        }
        if c.free_cores() != total || c.occupied_nodes() != 0 {
            return Err("cluster not pristine after full release".into());
        }
        Ok(())
    });
}

#[test]
fn allocation_never_exceeds_node_capacity() {
    check("node capacity", |rng| {
        let mut c = random_cluster(rng);
        let mut live = Vec::new();
        for step in 0..60u64 {
            let job = Job::simple(step, 0, rng.range(1, 40), 10);
            if let Some(a) = c.allocate(&job, policy(rng)) {
                live.push(a);
            }
            for n in c.nodes() {
                if n.free_cores > n.cores {
                    return Err(format!("node {} over capacity", n.id));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn best_fit_single_node_is_optimal() {
    check("best-fit optimality", |rng| {
        let mut c = random_cluster(rng);
        // Random pre-load.
        let mut step = 1000;
        for _ in 0..rng.below(8) {
            let j = Job::simple(step, 0, rng.range(1, 8), 10);
            let _ = c.allocate(&j, AllocPolicy::FirstFit);
            step += 1;
        }
        let req = rng.range(1, 16);
        let job = Job::simple(1, 0, req, 10);
        let before = c.clone();
        if let Some(a) = c.allocate(&job, AllocPolicy::BestFit) {
            if a.taken.len() == 1 {
                let (nid, _, _) = a.taken[0];
                let chosen_slack = before.nodes()[nid].free_cores - req;
                // No other node that fits has smaller slack.
                for n in before.nodes() {
                    if n.free_cores >= req && n.free_cores - req < chosen_slack {
                        return Err(format!(
                            "node {} slack {} beats chosen {} slack {}",
                            n.id,
                            n.free_cores - req,
                            nid,
                            chosen_slack
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn failed_allocation_leaves_cluster_untouched() {
    check("failed allocation purity", |rng| {
        let mut c = random_cluster(rng);
        let total = c.total_cores();
        // Fill most of the machine.
        let filler = Job::simple(1, 0, total.saturating_sub(1).max(1), 10);
        let _a = c.allocate(&filler, AllocPolicy::FirstFit);
        let free_before = c.free_cores();
        let snapshot: Vec<u64> = c.nodes().iter().map(|n| n.free_cores).collect();
        // This cannot fit.
        let big = Job::simple(2, 0, total + rng.range(1, 100), 10);
        if c.allocate(&big, policy(rng)).is_some() {
            return Err("impossible allocation succeeded".into());
        }
        if c.free_cores() != free_before {
            return Err("failed allocation changed free count".into());
        }
        let after: Vec<u64> = c.nodes().iter().map(|n| n.free_cores).collect();
        if snapshot != after {
            return Err("failed allocation mutated node state".into());
        }
        Ok(())
    });
}
