//! Cross-module integration tests: config -> workload -> simulation ->
//! metrics, trace round-trips through the full pipeline, and the CLI
//! binary itself.

use sst_sched::config::ExperimentConfig;
use sst_sched::core::time::SimDuration;
use sst_sched::parallel::{run_jobs_parallel_opts, RankSimOpts};
use sst_sched::sched::{Policy, PreemptionConfig, PreemptionMode};
use sst_sched::sim::{run_policy, FaultConfig, ReservationSpec, SimReport, Simulation};
use sst_sched::trace::{parse_swf, write_swf, Das2Model, SdscSp2Model};

#[test]
fn config_to_simulation_pipeline() {
    let cfg = ExperimentConfig::parse(
        r#"{
            "workload": {"kind": "sdsc-sp2", "jobs": 800, "seed": 3},
            "scheduler": {"policy": "sjf"}
        }"#,
    )
    .unwrap();
    let w = cfg.build_workload().unwrap();
    assert_eq!(w.nodes, 128);
    let r = run_policy(w, cfg.policy);
    assert_eq!(r.policy, "sjf");
    assert!(r.completed.len() >= 790); // a few rejects possible
    assert!(r.wait_stats().jobs == r.completed.len());
}

#[test]
fn swf_roundtrip_through_simulator() {
    // Generate -> write SWF -> parse SWF -> simulate both -> identical.
    let w = Das2Model::default().generate(500, 9).drop_infeasible();
    let text = write_swf(&w.jobs, "roundtrip");
    let parsed = parse_swf(&text).unwrap();
    assert_eq!(parsed.len(), w.jobs.len());
    let w2 = sst_sched::trace::Workload::new("reparsed", parsed, w.nodes, w.cores_per_node);
    let a = run_policy(w.clone(), Policy::FcfsBackfill);
    let b = run_policy(w2, Policy::FcfsBackfill);
    assert_eq!(a.events, b.events);
    assert_eq!(a.end_time, b.end_time);
    let starts = |r: &sst_sched::sim::SimReport| {
        let mut v: Vec<(u64, u64)> =
            r.completed.iter().map(|j| (j.id, j.start.unwrap().ticks())).collect();
        v.sort_unstable();
        v
    };
    assert_eq!(starts(&a), starts(&b));
}

#[test]
fn both_workload_models_run_under_all_policies() {
    for (name, w) in [
        ("das2", Das2Model::default().generate(600, 1).drop_infeasible()),
        ("sp2", SdscSp2Model::default().generate(400, 1).drop_infeasible()),
    ] {
        let n = w.jobs.len();
        for p in Policy::ALL {
            let r = run_policy(w.clone(), p);
            assert_eq!(r.completed.len(), n, "{name}/{p} lost jobs");
        }
    }
}

#[test]
fn utilization_series_is_bounded() {
    let w = SdscSp2Model::default().generate(1_000, 5).drop_infeasible();
    let r = run_policy(w, Policy::FcfsBackfill);
    for &(_, u) in r.utilization.points() {
        assert!((0.0..=1.0).contains(&u), "utilization {u} out of range");
    }
    assert!(r.mean_utilization > 0.0 && r.mean_utilization <= 1.0);
}

#[test]
fn occupancy_ends_at_zero_when_queue_drains() {
    let w = Das2Model::default().generate(800, 2).drop_infeasible();
    let r = run_policy(w, Policy::Fcfs);
    assert_eq!(r.occupancy.points().last().unwrap().1, 0.0);
    assert_eq!(r.running.points().last().unwrap().1, 0.0);
}

fn fault_sim(policy: Policy) -> SimReport {
    let w = SdscSp2Model::default().generate(800, 13).drop_infeasible();
    let faults = FaultConfig { mtbf: 20_000.0, mttr: 4_000.0, seed: 77, ..FaultConfig::default() };
    let preemption = PreemptionConfig {
        mode: PreemptionMode::Checkpoint,
        checkpoint_overhead: SimDuration(60),
        restart_overhead: SimDuration(30),
        starvation_threshold: SimDuration(0),
    };
    Simulation::new(w, policy)
        .with_seed(5)
        .with_faults(faults)
        .with_preemption(preemption)
        .run(None)
}

/// Determinism regression (fault subsystem): with a fixed seed, a
/// fault-injected simulation produces byte-identical metrics across
/// repeated runs, for every policy.
#[test]
fn fault_injected_runs_are_bit_reproducible() {
    for policy in Policy::ALL {
        let a = fault_sim(policy).fingerprint();
        let b = fault_sim(policy).fingerprint();
        assert_eq!(a, b, "{policy} fault run not reproducible");
        assert!(a.contains("failures="), "fingerprint missing counters: {a}");
    }
    // And the fingerprint actually distinguishes different runs.
    let base = fault_sim(Policy::Fcfs).fingerprint();
    let other = {
        let w = SdscSp2Model::default().generate(800, 13).drop_infeasible();
        let faults = FaultConfig { mtbf: 20_000.0, mttr: 4_000.0, seed: 78, ..FaultConfig::default() };
        Simulation::new(w, Policy::Fcfs).with_seed(5).with_faults(faults).run(None).fingerprint()
    };
    assert_ne!(base, other, "different fault seeds must change the fingerprint");
}

/// Determinism across the parallel engine: at every rank count, the
/// threaded run equals the serially-modeled run (thread interleaving
/// cannot change results) and repeated threaded runs are byte-identical
/// — including per-rank result digests — with fault injection active.
#[test]
fn parallel_fault_runs_deterministic_across_thread_counts() {
    let w = Das2Model::default().generate(600, 9).drop_infeasible();
    let opts = RankSimOpts {
        seed: 3,
        faults: FaultConfig { mtbf: 15_000.0, mttr: 3_000.0, seed: 21, ..FaultConfig::default() },
        ..RankSimOpts::default()
    };
    for ranks in [1usize, 2, 4] {
        let threaded1 =
            run_jobs_parallel_opts(&w, Policy::FcfsBackfill, ranks, 3_600, &opts, true);
        let threaded2 =
            run_jobs_parallel_opts(&w, Policy::FcfsBackfill, ranks, 3_600, &opts, true);
        let modeled =
            run_jobs_parallel_opts(&w, Policy::FcfsBackfill, ranks, 3_600, &opts, false);
        assert_eq!(
            threaded1.summaries, threaded2.summaries,
            "ranks={ranks}: repeated threaded runs differ"
        );
        assert_eq!(
            threaded1.summaries, modeled.summaries,
            "ranks={ranks}: threads changed simulation results"
        );
        assert!(
            threaded1.summaries.iter().all(|s| s.fingerprint != 0),
            "ranks={ranks}: missing per-rank digests"
        );
        assert_eq!(threaded1.total_completed(), w.jobs.len() as u64, "ranks={ranks} lost jobs");
    }
}

/// Acceptance test of the availability-timeline refactor: EASY must
/// refuse a backfill candidate whose run would collide with a *future*
/// advance reservation. Before the shared profile, reservations only
/// claimed nodes at their start time, so the release-walk backfill
/// admitted the candidate at t=0 (it "finished by the shadow time") and
/// the reservation then had to drain around it.
#[test]
fn backfill_plans_around_future_reservation() {
    use sst_sched::job::Job;
    use sst_sched::trace::Workload;
    // 2 nodes x 4 cores. j1 occupies half the machine until t=100; j2
    // (head) wants everything; j3 is classic backfill fodder (4 cores,
    // 50 ticks). A reservation takes the whole machine over [30, 130).
    let jobs = vec![
        Job::with_estimate(1, 0, 4, 100, 100),
        Job::with_estimate(2, 0, 8, 100, 100),
        Job::with_estimate(3, 0, 4, 50, 50),
    ];
    let w = Workload::new("resv-aware", jobs, 2, 4);
    let resv = vec![ReservationSpec { start: 30, duration: 100, nodes: 2 }];
    let r = Simulation::new(w, Policy::FcfsBackfill).with_reservations(resv).run(None);
    assert_eq!(r.completed.len(), 3);
    let start =
        |id: u64| r.completed.iter().find(|j| j.id == id).unwrap().start.unwrap().ticks();
    assert_eq!(start(1), 0, "phase-1 start untouched");
    // The candidate's [0, 50) run collides with the reservation window:
    // the release-walk EASY started it at t=0, the planner must not.
    assert!(start(3) > 0, "j3 must not backfill into the reservation window");
    // Head waits out the reservation (it needs the whole machine), then
    // the candidate runs after it.
    assert_eq!(start(2), 130);
    assert_eq!(start(3), 230);
    // Nobody was running on reserved nodes except the pre-existing j1,
    // which drained (reservation degraded on exactly its node).
    assert_eq!(r.faults.preemptions, 0);
    assert_eq!(r.faults.reservations_degraded, 1);
    assert_eq!(r.faults.reservations_short_nodes, 0);
}

/// Finite-horizon refresh: a reservation whose window lies *beyond* the
/// planning horizon at simulation start is clamped out of the initial
/// timeline, but must re-enter as time approaches it (the dispatch
/// refresh every horizon/2 ticks) — a candidate colliding with it is
/// still refused. If the refresh regresses, the window stays invisible,
/// the candidate backfills at t=95, and the start-time assertions fail.
#[test]
fn horizon_refresh_replans_far_reservations() {
    use sst_sched::job::Job;
    use sst_sched::trace::Workload;
    // 2 nodes x 4 cores, horizon 60 ticks. Reservation [130, 230) over
    // the whole machine — invisible at t=0 (0 + 60 < 130).
    let jobs = vec![
        Job::with_estimate(1, 0, 4, 200, 200),  // runs [0, 200) on node 0
        Job::with_estimate(2, 0, 8, 100, 100),  // head: blocked behind j1
        Job::with_estimate(3, 95, 4, 50, 50),   // candidate at t=95
    ];
    let w = Workload::new("horizon-refresh", jobs, 2, 4);
    let resv = vec![ReservationSpec { start: 130, duration: 100, nodes: 2 }];
    let r = Simulation::new(w, Policy::FcfsBackfill)
        .with_reservations(resv)
        .with_planning_horizon(60)
        .run(None);
    assert_eq!(r.completed.len(), 3);
    let start =
        |id: u64| r.completed.iter().find(|j| j.id == id).unwrap().start.unwrap().ticks();
    assert_eq!(start(1), 0);
    // At t=95 the refresh has re-planned the window (95 - 0 >= 60/2), so
    // j3's [95, 145) run collides with [130, 230) and must wait; both
    // remaining jobs run after the reservation expires at 230.
    assert_eq!(start(2), 230, "head must wait out the reservation");
    assert_eq!(start(3), 330, "candidate must not backfill into the window");
}

/// The planning horizon bounds timeline fidelity, not correctness:
/// every job still completes, and an unlimited-horizon run of the same
/// seeded workload matches itself.
#[test]
fn planning_horizon_keeps_runs_complete_and_deterministic() {
    let w = SdscSp2Model::default().generate(600, 5).drop_infeasible();
    let n = w.jobs.len();
    for horizon in [0u64, 3_600, 86_400] {
        let run = |w: sst_sched::trace::Workload| {
            Simulation::new(w, Policy::FcfsBackfill)
                .with_planning_horizon(horizon)
                .run(None)
        };
        let a = run(w.clone());
        assert_eq!(a.completed.len(), n, "horizon {horizon} lost jobs");
        let b = run(w.clone());
        assert_eq!(a.fingerprint(), b.fingerprint(), "horizon {horizon} not reproducible");
    }
}

#[test]
fn weibull_faults_run_deterministic_and_complete() {
    let w = SdscSp2Model::default().generate(500, 9).drop_infeasible();
    let n = w.jobs.len();
    let faults = FaultConfig {
        mtbf: 8_000.0,
        mttr: 2_000.0,
        seed: 31,
        distribution: sst_sched::sim::FaultDistribution::Weibull,
        shape: 0.7,
        ..FaultConfig::default()
    };
    let preemption = PreemptionConfig {
        mode: PreemptionMode::Checkpoint,
        checkpoint_overhead: SimDuration(30),
        restart_overhead: SimDuration(30),
        starvation_threshold: SimDuration(0),
    };
    let run = |w: sst_sched::trace::Workload| {
        Simulation::new(w, Policy::FcfsBackfill)
            .with_faults(faults)
            .with_preemption(preemption)
            .run(None)
    };
    let a = run(w.clone());
    assert_eq!(a.completed.len(), n);
    assert!(a.faults.failures > 0, "weibull trace injected nothing");
    assert_eq!(a.fingerprint(), run(w.clone()).fingerprint());
    // A different shape changes the failure trace.
    let other = Simulation::new(w, Policy::FcfsBackfill)
        .with_faults(FaultConfig { shape: 3.0, ..faults })
        .with_preemption(preemption)
        .run(None);
    assert_ne!(a.fingerprint(), other.fingerprint(), "shape knob must matter");
}

#[test]
fn cli_run_with_faults_reports_subsystem() {
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let out = std::process::Command::new(exe)
        .args([
            "run", "--workload", "das2", "--jobs", "400", "--policy", "fcfs-backfill",
            "--mtbf", "8000", "--mttr", "2000", "--faults-seed", "5",
            "--preemption", "checkpoint", "--ckpt-overhead", "30", "--restart-overhead", "30",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("preemption mode   checkpoint"), "{text}");
    assert!(text.contains("node failures"), "{text}");
    assert!(text.contains("effective util"), "{text}");
}

#[test]
fn cli_binary_help_and_policies() {
    // The binary is built by the test harness's dependency graph only in
    // some cargo invocations; fall back to skipping when absent.
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let out = std::process::Command::new(exe).arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("USAGE"));

    let out = std::process::Command::new(exe).arg("policies").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for p in ["fcfs", "sjf", "ljf", "fcfs-bestfit", "fcfs-backfill", "cons-backfill"] {
        assert!(text.contains(p), "policies output missing {p}");
    }
}

#[test]
fn cli_run_and_trace_info() {
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let out = std::process::Command::new(exe)
        .args(["run", "--workload", "das2", "--jobs", "300", "--policy", "fcfs"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("jobs completed    300"), "{text}");

    let out = std::process::Command::new(exe)
        .args(["trace-info", "--workload", "sdsc-sp2", "--jobs", "500"])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("128 nodes"));
}

#[test]
fn cli_rejects_unknown_options() {
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let out = std::process::Command::new(exe)
        .args(["run", "--jbs", "300"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("jbs"));
}

#[test]
fn cli_workflow_spec() {
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let out = std::process::Command::new(exe)
        .args(["workflow", "--spec", "examples/workflows/listing2.json"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("makespan     600 s"), "{text}");
}
