//! Cross-module integration tests: config -> workload -> simulation ->
//! metrics, trace round-trips through the full pipeline, and the CLI
//! binary itself.

use sst_sched::config::ExperimentConfig;
use sst_sched::core::time::SimDuration;
use sst_sched::parallel::{run_jobs_parallel_opts, RankSimOpts};
use sst_sched::sched::{Policy, PreemptionConfig, PreemptionMode};
use sst_sched::sim::{run_policy, FaultConfig, SimReport, Simulation};
use sst_sched::trace::{parse_swf, write_swf, Das2Model, SdscSp2Model};

#[test]
fn config_to_simulation_pipeline() {
    let cfg = ExperimentConfig::parse(
        r#"{
            "workload": {"kind": "sdsc-sp2", "jobs": 800, "seed": 3},
            "scheduler": {"policy": "sjf"}
        }"#,
    )
    .unwrap();
    let w = cfg.build_workload().unwrap();
    assert_eq!(w.nodes, 128);
    let r = run_policy(w, cfg.policy);
    assert_eq!(r.policy, "sjf");
    assert!(r.completed.len() >= 790); // a few rejects possible
    assert!(r.wait_stats().jobs == r.completed.len());
}

#[test]
fn swf_roundtrip_through_simulator() {
    // Generate -> write SWF -> parse SWF -> simulate both -> identical.
    let w = Das2Model::default().generate(500, 9).drop_infeasible();
    let text = write_swf(&w.jobs, "roundtrip");
    let parsed = parse_swf(&text).unwrap();
    assert_eq!(parsed.len(), w.jobs.len());
    let w2 = sst_sched::trace::Workload::new("reparsed", parsed, w.nodes, w.cores_per_node);
    let a = run_policy(w.clone(), Policy::FcfsBackfill);
    let b = run_policy(w2, Policy::FcfsBackfill);
    assert_eq!(a.events, b.events);
    assert_eq!(a.end_time, b.end_time);
    let starts = |r: &sst_sched::sim::SimReport| {
        let mut v: Vec<(u64, u64)> =
            r.completed.iter().map(|j| (j.id, j.start.unwrap().ticks())).collect();
        v.sort_unstable();
        v
    };
    assert_eq!(starts(&a), starts(&b));
}

#[test]
fn both_workload_models_run_under_all_policies() {
    for (name, w) in [
        ("das2", Das2Model::default().generate(600, 1).drop_infeasible()),
        ("sp2", SdscSp2Model::default().generate(400, 1).drop_infeasible()),
    ] {
        let n = w.jobs.len();
        for p in Policy::ALL {
            let r = run_policy(w.clone(), p);
            assert_eq!(r.completed.len(), n, "{name}/{p} lost jobs");
        }
    }
}

#[test]
fn utilization_series_is_bounded() {
    let w = SdscSp2Model::default().generate(1_000, 5).drop_infeasible();
    let r = run_policy(w, Policy::FcfsBackfill);
    for &(_, u) in r.utilization.points() {
        assert!((0.0..=1.0).contains(&u), "utilization {u} out of range");
    }
    assert!(r.mean_utilization > 0.0 && r.mean_utilization <= 1.0);
}

#[test]
fn occupancy_ends_at_zero_when_queue_drains() {
    let w = Das2Model::default().generate(800, 2).drop_infeasible();
    let r = run_policy(w, Policy::Fcfs);
    assert_eq!(r.occupancy.points().last().unwrap().1, 0.0);
    assert_eq!(r.running.points().last().unwrap().1, 0.0);
}

fn fault_sim(policy: Policy) -> SimReport {
    let w = SdscSp2Model::default().generate(800, 13).drop_infeasible();
    let faults = FaultConfig { mtbf: 20_000.0, mttr: 4_000.0, seed: 77, until: None };
    let preemption = PreemptionConfig {
        mode: PreemptionMode::Checkpoint,
        checkpoint_overhead: SimDuration(60),
        restart_overhead: SimDuration(30),
        starvation_threshold: SimDuration(0),
    };
    Simulation::new(w, policy)
        .with_seed(5)
        .with_faults(faults)
        .with_preemption(preemption)
        .run(None)
}

/// Determinism regression (fault subsystem): with a fixed seed, a
/// fault-injected simulation produces byte-identical metrics across
/// repeated runs, for every policy.
#[test]
fn fault_injected_runs_are_bit_reproducible() {
    for policy in Policy::ALL {
        let a = fault_sim(policy).fingerprint();
        let b = fault_sim(policy).fingerprint();
        assert_eq!(a, b, "{policy} fault run not reproducible");
        assert!(a.contains("failures="), "fingerprint missing counters: {a}");
    }
    // And the fingerprint actually distinguishes different runs.
    let base = fault_sim(Policy::Fcfs).fingerprint();
    let other = {
        let w = SdscSp2Model::default().generate(800, 13).drop_infeasible();
        let faults = FaultConfig { mtbf: 20_000.0, mttr: 4_000.0, seed: 78, until: None };
        Simulation::new(w, Policy::Fcfs).with_seed(5).with_faults(faults).run(None).fingerprint()
    };
    assert_ne!(base, other, "different fault seeds must change the fingerprint");
}

/// Determinism across the parallel engine: at every rank count, the
/// threaded run equals the serially-modeled run (thread interleaving
/// cannot change results) and repeated threaded runs are byte-identical
/// — including per-rank result digests — with fault injection active.
#[test]
fn parallel_fault_runs_deterministic_across_thread_counts() {
    let w = Das2Model::default().generate(600, 9).drop_infeasible();
    let opts = RankSimOpts {
        seed: 3,
        faults: FaultConfig { mtbf: 15_000.0, mttr: 3_000.0, seed: 21, until: None },
        preemption: PreemptionConfig::default(),
        reservations: Vec::new(),
    };
    for ranks in [1usize, 2, 4] {
        let threaded1 =
            run_jobs_parallel_opts(&w, Policy::FcfsBackfill, ranks, 3_600, &opts, true);
        let threaded2 =
            run_jobs_parallel_opts(&w, Policy::FcfsBackfill, ranks, 3_600, &opts, true);
        let modeled =
            run_jobs_parallel_opts(&w, Policy::FcfsBackfill, ranks, 3_600, &opts, false);
        assert_eq!(
            threaded1.summaries, threaded2.summaries,
            "ranks={ranks}: repeated threaded runs differ"
        );
        assert_eq!(
            threaded1.summaries, modeled.summaries,
            "ranks={ranks}: threads changed simulation results"
        );
        assert!(
            threaded1.summaries.iter().all(|s| s.fingerprint != 0),
            "ranks={ranks}: missing per-rank digests"
        );
        assert_eq!(threaded1.total_completed(), w.jobs.len() as u64, "ranks={ranks} lost jobs");
    }
}

#[test]
fn cli_run_with_faults_reports_subsystem() {
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let out = std::process::Command::new(exe)
        .args([
            "run", "--workload", "das2", "--jobs", "400", "--policy", "fcfs-backfill",
            "--mtbf", "8000", "--mttr", "2000", "--faults-seed", "5",
            "--preemption", "checkpoint", "--ckpt-overhead", "30", "--restart-overhead", "30",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("preemption mode   checkpoint"), "{text}");
    assert!(text.contains("node failures"), "{text}");
    assert!(text.contains("effective util"), "{text}");
}

#[test]
fn cli_binary_help_and_policies() {
    // The binary is built by the test harness's dependency graph only in
    // some cargo invocations; fall back to skipping when absent.
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let out = std::process::Command::new(exe).arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("USAGE"));

    let out = std::process::Command::new(exe).arg("policies").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for p in ["fcfs", "sjf", "ljf", "fcfs-bestfit", "fcfs-backfill", "cons-backfill"] {
        assert!(text.contains(p), "policies output missing {p}");
    }
}

#[test]
fn cli_run_and_trace_info() {
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let out = std::process::Command::new(exe)
        .args(["run", "--workload", "das2", "--jobs", "300", "--policy", "fcfs"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("jobs completed    300"), "{text}");

    let out = std::process::Command::new(exe)
        .args(["trace-info", "--workload", "sdsc-sp2", "--jobs", "500"])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("128 nodes"));
}

#[test]
fn cli_rejects_unknown_options() {
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let out = std::process::Command::new(exe)
        .args(["run", "--jbs", "300"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("jbs"));
}

#[test]
fn cli_workflow_spec() {
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let out = std::process::Command::new(exe)
        .args(["workflow", "--spec", "examples/workflows/listing2.json"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("makespan     600 s"), "{text}");
}
