//! Cross-module integration tests: config -> workload -> simulation ->
//! metrics, trace round-trips through the full pipeline, and the CLI
//! binary itself.

use sst_sched::config::ExperimentConfig;
use sst_sched::core::time::SimDuration;
use sst_sched::parallel::{run_jobs_parallel_opts, RankSimOpts};
use sst_sched::sched::{Policy, PreemptionConfig, PreemptionMode};
use sst_sched::sim::{run_policy, FaultConfig, ReservationSpec, SimReport, Simulation};
use sst_sched::trace::{parse_swf, write_swf, Das2Model, SdscSp2Model};

#[test]
fn config_to_simulation_pipeline() {
    let cfg = ExperimentConfig::parse(
        r#"{
            "workload": {"kind": "sdsc-sp2", "jobs": 800, "seed": 3},
            "scheduler": {"policy": "sjf"}
        }"#,
    )
    .unwrap();
    let w = cfg.build_workload().unwrap();
    assert_eq!(w.nodes, 128);
    let r = run_policy(w, cfg.policy);
    assert_eq!(r.policy, "sjf");
    assert!(r.completed.len() >= 790); // a few rejects possible
    assert!(r.wait_stats().jobs == r.completed.len());
}

#[test]
fn swf_roundtrip_through_simulator() {
    // Generate -> write SWF -> parse SWF -> simulate both -> identical.
    let w = Das2Model::default().generate(500, 9).drop_infeasible();
    let text = write_swf(&w.jobs, "roundtrip");
    let parsed = parse_swf(&text).unwrap();
    assert_eq!(parsed.len(), w.jobs.len());
    let w2 = sst_sched::trace::Workload::new("reparsed", parsed, w.nodes, w.cores_per_node);
    let a = run_policy(w.clone(), Policy::FcfsBackfill);
    let b = run_policy(w2, Policy::FcfsBackfill);
    assert_eq!(a.events, b.events);
    assert_eq!(a.end_time, b.end_time);
    let starts = |r: &sst_sched::sim::SimReport| {
        let mut v: Vec<(u64, u64)> =
            r.completed.iter().map(|j| (j.id, j.start.unwrap().ticks())).collect();
        v.sort_unstable();
        v
    };
    assert_eq!(starts(&a), starts(&b));
}

#[test]
fn both_workload_models_run_under_all_policies() {
    for (name, w) in [
        ("das2", Das2Model::default().generate(600, 1).drop_infeasible()),
        ("sp2", SdscSp2Model::default().generate(400, 1).drop_infeasible()),
    ] {
        let n = w.jobs.len();
        for p in Policy::ALL {
            let r = run_policy(w.clone(), p);
            assert_eq!(r.completed.len(), n, "{name}/{p} lost jobs");
        }
    }
}

#[test]
fn utilization_series_is_bounded() {
    let w = SdscSp2Model::default().generate(1_000, 5).drop_infeasible();
    let r = run_policy(w, Policy::FcfsBackfill);
    for &(_, u) in r.utilization.points() {
        assert!((0.0..=1.0).contains(&u), "utilization {u} out of range");
    }
    assert!(r.mean_utilization > 0.0 && r.mean_utilization <= 1.0);
}

#[test]
fn occupancy_ends_at_zero_when_queue_drains() {
    let w = Das2Model::default().generate(800, 2).drop_infeasible();
    let r = run_policy(w, Policy::Fcfs);
    assert_eq!(r.occupancy.points().last().unwrap().1, 0.0);
    assert_eq!(r.running.points().last().unwrap().1, 0.0);
}

fn fault_sim(policy: Policy) -> SimReport {
    let w = SdscSp2Model::default().generate(800, 13).drop_infeasible();
    let faults = FaultConfig { mtbf: 20_000.0, mttr: 4_000.0, seed: 77, ..FaultConfig::default() };
    let preemption = PreemptionConfig {
        mode: PreemptionMode::Checkpoint,
        checkpoint_overhead: SimDuration(60),
        restart_overhead: SimDuration(30),
        starvation_threshold: SimDuration(0),
    };
    Simulation::new(w, policy)
        .with_seed(5)
        .with_faults(faults)
        .with_preemption(preemption)
        .run(None)
}

/// Determinism regression (fault subsystem): with a fixed seed, a
/// fault-injected simulation produces byte-identical metrics across
/// repeated runs, for every policy.
#[test]
fn fault_injected_runs_are_bit_reproducible() {
    for policy in Policy::ALL {
        let a = fault_sim(policy).fingerprint();
        let b = fault_sim(policy).fingerprint();
        assert_eq!(a, b, "{policy} fault run not reproducible");
        assert!(a.contains("failures="), "fingerprint missing counters: {a}");
    }
    // And the fingerprint actually distinguishes different runs.
    let base = fault_sim(Policy::Fcfs).fingerprint();
    let other = {
        let w = SdscSp2Model::default().generate(800, 13).drop_infeasible();
        let faults = FaultConfig { mtbf: 20_000.0, mttr: 4_000.0, seed: 78, ..FaultConfig::default() };
        Simulation::new(w, Policy::Fcfs).with_seed(5).with_faults(faults).run(None).fingerprint()
    };
    assert_ne!(base, other, "different fault seeds must change the fingerprint");
}

/// Determinism across the parallel engine: at every rank count, the
/// threaded run equals the serially-modeled run (thread interleaving
/// cannot change results) and repeated threaded runs are byte-identical
/// — including per-rank result digests — with fault injection active.
#[test]
fn parallel_fault_runs_deterministic_across_thread_counts() {
    let w = Das2Model::default().generate(600, 9).drop_infeasible();
    let opts = RankSimOpts {
        seed: 3,
        faults: FaultConfig { mtbf: 15_000.0, mttr: 3_000.0, seed: 21, ..FaultConfig::default() },
        ..RankSimOpts::default()
    };
    for ranks in [1usize, 2, 4] {
        let threaded1 =
            run_jobs_parallel_opts(&w, Policy::FcfsBackfill, ranks, 3_600, &opts, true);
        let threaded2 =
            run_jobs_parallel_opts(&w, Policy::FcfsBackfill, ranks, 3_600, &opts, true);
        let modeled =
            run_jobs_parallel_opts(&w, Policy::FcfsBackfill, ranks, 3_600, &opts, false);
        assert_eq!(
            threaded1.summaries, threaded2.summaries,
            "ranks={ranks}: repeated threaded runs differ"
        );
        assert_eq!(
            threaded1.summaries, modeled.summaries,
            "ranks={ranks}: threads changed simulation results"
        );
        assert!(
            threaded1.summaries.iter().all(|s| s.fingerprint != 0),
            "ranks={ranks}: missing per-rank digests"
        );
        assert_eq!(threaded1.total_completed(), w.jobs.len() as u64, "ranks={ranks} lost jobs");
    }
}

/// Acceptance test of the availability-timeline refactor (updated for
/// the multi-resource/ordering redesign): every start — phase-1 FCFS
/// starts included — must clear a *future* advance reservation window.
/// Before the shared profile, reservations only claimed nodes at their
/// start time, so backfill admitted colliding candidates; before the
/// ordering redesign, phase-1/blocking starts still ran into the window
/// (the reservation had to degrade around them). Now the whole queue
/// waits, the reservation claims an idle machine cleanly, and backfill
/// resumes on the far side of the window.
#[test]
fn backfill_plans_around_future_reservation() {
    use sst_sched::job::Job;
    use sst_sched::trace::Workload;
    // 2 nodes x 4 cores. j1 wants half the machine for 100 ticks; j2
    // (head) wants everything; j3 is classic backfill fodder (4 cores,
    // 50 ticks). A reservation takes the whole machine over [30, 130).
    let jobs = vec![
        Job::with_estimate(1, 0, 4, 100, 100),
        Job::with_estimate(2, 0, 8, 100, 100),
        Job::with_estimate(3, 0, 4, 50, 50),
    ];
    let w = Workload::new("resv-aware", jobs, 2, 4);
    let resv = vec![ReservationSpec { start: 30, duration: 100, nodes: 2 }];
    let r = Simulation::new(w, Policy::FcfsBackfill).with_reservations(resv).run(None);
    assert_eq!(r.completed.len(), 3);
    let start =
        |id: u64| r.completed.iter().find(|j| j.id == id).unwrap().start.unwrap().ticks();
    // j1's [0, 100) run would collide with the window: it waits too
    // (this is the blocking-discipline half of the redesign).
    assert_eq!(start(1), 130, "phase-1 start must clear the reservation window");
    // Once the window passes, j2 is the blocked head (j1 holds half the
    // machine) and j3 backfills beside j1 without delaying j2.
    assert_eq!(start(3), 130, "j3 backfills right after the window");
    assert_eq!(start(2), 230, "head runs when j1 releases");
    // The machine was idle at claim time: clean claim, no draining, no
    // preemption.
    assert_eq!(r.faults.preemptions, 0);
    assert_eq!(r.faults.reservations_degraded, 0);
    assert_eq!(r.faults.reservations_short_nodes, 0);
}

/// Finite-horizon refresh: a reservation whose window lies *beyond* the
/// planning horizon at simulation start is clamped out of the initial
/// timeline, but must re-enter as time approaches it (the dispatch
/// refresh every horizon/2 ticks) — a candidate colliding with it is
/// still refused. If the refresh regresses, the window stays invisible,
/// the candidate backfills at t=95, and the start-time assertions fail.
#[test]
fn horizon_refresh_replans_far_reservations() {
    use sst_sched::job::Job;
    use sst_sched::trace::Workload;
    // 2 nodes x 4 cores, horizon 60 ticks. Reservation [130, 230) over
    // the whole machine — invisible at t=0 (0 + 60 < 130).
    let jobs = vec![
        Job::with_estimate(1, 0, 4, 200, 200),  // runs [0, 200) on node 0
        Job::with_estimate(2, 0, 8, 100, 100),  // head: blocked behind j1
        Job::with_estimate(3, 95, 4, 50, 50),   // candidate at t=95
    ];
    let w = Workload::new("horizon-refresh", jobs, 2, 4);
    let resv = vec![ReservationSpec { start: 130, duration: 100, nodes: 2 }];
    let r = Simulation::new(w, Policy::FcfsBackfill)
        .with_reservations(resv)
        .with_planning_horizon(60)
        .run(None);
    assert_eq!(r.completed.len(), 3);
    let start =
        |id: u64| r.completed.iter().find(|j| j.id == id).unwrap().start.unwrap().ticks();
    assert_eq!(start(1), 0);
    // At t=95 the refresh has re-planned the window (95 - 0 >= 60/2), so
    // j3's [95, 145) run collides with [130, 230) and must wait; both
    // remaining jobs run after the reservation expires at 230.
    assert_eq!(start(2), 230, "head must wait out the reservation");
    assert_eq!(start(3), 330, "candidate must not backfill into the window");
}

/// Acceptance test of the queue-ordering/multi-resource redesign, part
/// 1: plain FCFS (a blocking discipline that never read the timeline
/// before) now *waits* instead of starting into a future reservation
/// window. Pre-redesign the head started at t=0 because the cores were
/// free at that instant, and the reservation then had to degrade.
#[test]
fn fcfs_head_waits_for_future_reservation() {
    use sst_sched::job::Job;
    use sst_sched::trace::Workload;
    // 2 nodes x 4 cores, all idle. Head j1 wants the whole machine for
    // 50 ticks; a reservation takes both nodes over [30, 130). j2 fits
    // trivially but must stay blocked behind the head (FCFS).
    let jobs = vec![
        Job::with_estimate(1, 0, 8, 50, 50),
        Job::with_estimate(2, 1, 1, 10, 10),
    ];
    let w = Workload::new("fcfs-resv", jobs, 2, 4);
    let resv = vec![ReservationSpec { start: 30, duration: 100, nodes: 2 }];
    let r = Simulation::new(w, Policy::Fcfs).with_reservations(resv).run(None);
    assert_eq!(r.completed.len(), 2);
    let start =
        |id: u64| r.completed.iter().find(|j| j.id == id).unwrap().start.unwrap().ticks();
    assert_eq!(start(1), 130, "blocked head must wait out the reservation window");
    assert!(start(2) >= 130, "FCFS discipline: nothing leapfrogs the blocked head");
    // The machine was idle when the reservation came due: a clean claim,
    // no draining, no degradation — the whole point of waiting.
    assert_eq!(r.faults.reservations_degraded, 0);
    assert_eq!(r.faults.reservations_short_nodes, 0);
}

/// Part 2: `--order fair-share` composes with every policy and stays
/// byte-deterministic across repeat runs (acceptance criterion).
#[test]
fn fairshare_order_composes_with_all_policies_deterministically() {
    use sst_sched::sched::OrderKind;
    let w = SdscSp2Model::default().generate(500, 17).scale_arrivals(0.6).drop_infeasible();
    let n = w.jobs.len();
    for policy in Policy::ALL {
        let run = |w: sst_sched::trace::Workload| {
            Simulation::new(w, policy)
                .with_order(OrderKind::FairShare)
                .with_fairshare_half_life(7_200)
                .run(None)
        };
        let a = run(w.clone());
        assert_eq!(a.completed.len(), n, "{policy} lost jobs under fair-share");
        assert_eq!(a.order, "fair-share");
        assert!(!a.user_shares.is_empty(), "{policy}: no usage charged");
        let b = run(w.clone());
        assert_eq!(a.fingerprint(), b.fingerprint(), "{policy} fair-share not reproducible");
    }
}

/// Part 3: fair share actually redistributes — a user who has consumed
/// heavily yields the machine to a light user, where arrival order
/// would make the newcomer wait behind the hog's whole backlog.
#[test]
fn fairshare_prioritizes_light_users() {
    use sst_sched::job::Job;
    use sst_sched::sched::OrderKind;
    use sst_sched::trace::Workload;
    // 1 node x 4 cores. User 1 submits four machine-filling jobs at
    // t=0..3; user 2 submits one at t=4. FCFS runs user 1's backlog
    // first (user 2 starts at t=300); fair share lets user 2 in right
    // after user 1's first job completes.
    let jobs = || -> Vec<Job> {
        let mut out: Vec<Job> = (0..4)
            .map(|i| {
                let mut j = Job::simple(i + 1, i, 4, 100);
                j.user = 1;
                j
            })
            .collect();
        let mut late = Job::simple(9, 4, 4, 100);
        late.user = 2;
        out.push(late);
        out
    };
    let wait9 = |r: &SimReport| {
        r.completed.iter().find(|j| j.id == 9).unwrap().wait_time().unwrap().ticks()
    };
    let fcfs = run_policy(Workload::new("hog", jobs(), 1, 4), Policy::Fcfs);
    let fair = Simulation::new(Workload::new("hog", jobs(), 1, 4), Policy::Fcfs)
        .with_order(OrderKind::FairShare)
        .with_fairshare_half_life(86_400)
        .run(None);
    assert!(
        wait9(&fair) < wait9(&fcfs),
        "fair share must cut the light user's wait: {} !< {}",
        wait9(&fair),
        wait9(&fcfs)
    );
    assert_eq!(fair.completed.len(), 5);
    // The ledger knows both users.
    assert!(fair.user_shares.iter().any(|s| s.user == 1));
    assert!(fair.user_shares.iter().any(|s| s.user == 2));
}

/// Part 4: memory-aware planning is exactly inert when no job carries a
/// memory demand — bit-identical fingerprints with the flag on and off
/// (the lazy second dimension never materializes), the acceptance
/// criterion for cores-only configurations.
#[test]
fn memory_awareness_is_inert_without_memory_demands() {
    let w = SdscSp2Model::default().generate(600, 23).drop_infeasible();
    let run = |memory_aware: bool| {
        Simulation::new(w.clone(), Policy::FcfsBackfill)
            .with_mem_per_node(4096)
            .with_memory_aware(memory_aware)
            .run(None)
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off.fingerprint(), on.fingerprint(), "memory awareness changed a cores-only run");
    assert!(on.mean_memory_utilization >= 0.0);
}

/// Part 5: with memory demands present, the memory-aware run completes
/// everything, never over-plans aggregate memory (utilization bounded),
/// and stays deterministic.
#[test]
fn memory_aware_runs_complete_and_bound_memory() {
    let mut w = SdscSp2Model::default().generate(600, 29).drop_infeasible();
    // Attach synthetic memory demands: heavier for wider jobs, never
    // exceeding the per-node share the placement needs.
    for j in w.jobs.iter_mut() {
        j.memory_mb = (j.cores % 8 + 1) * 400;
    }
    let run = || {
        Simulation::new(w.clone(), Policy::ConservativeBackfill)
            .with_mem_per_node(16_384)
            .with_memory_aware(true)
            .run(None)
    };
    let r = run();
    assert_eq!(r.completed.len(), w.jobs.len(), "memory-aware run lost jobs");
    for &(_, u) in r.memory_utilization.points() {
        assert!((0.0..=1.0).contains(&u), "memory utilization {u} out of range");
    }
    assert!(r.mean_memory_utilization > 0.0, "memory series never recorded");
    assert_eq!(r.fingerprint(), run().fingerprint());
}

/// The planning horizon bounds timeline fidelity, not correctness:
/// every job still completes, and an unlimited-horizon run of the same
/// seeded workload matches itself.
#[test]
fn planning_horizon_keeps_runs_complete_and_deterministic() {
    let w = SdscSp2Model::default().generate(600, 5).drop_infeasible();
    let n = w.jobs.len();
    for horizon in [0u64, 3_600, 86_400] {
        let run = |w: sst_sched::trace::Workload| {
            Simulation::new(w, Policy::FcfsBackfill)
                .with_planning_horizon(horizon)
                .run(None)
        };
        let a = run(w.clone());
        assert_eq!(a.completed.len(), n, "horizon {horizon} lost jobs");
        let b = run(w.clone());
        assert_eq!(a.fingerprint(), b.fingerprint(), "horizon {horizon} not reproducible");
    }
}

/// Streaming ingestion == eager ingestion, end to end: the same SWF
/// bytes fed through `JobStream` + `with_job_stream` must produce a
/// byte-identical report fingerprint to parsing the whole trace up
/// front (streaming is pure plumbing — the acceptance criterion of the
/// million-job scale path).
#[test]
fn streamed_run_matches_eager_run_bit_for_bit() {
    use sst_sched::trace::{JobStream, TraceFormat, Workload};
    use std::io::Cursor;
    let w = SdscSp2Model::default().generate(2_000, 41).drop_infeasible();
    let text = write_swf(&w.jobs, "stream determinism");
    let eager_jobs = parse_swf(&text).unwrap();
    assert_eq!(eager_jobs.len(), w.jobs.len());
    let eager = run_policy(
        Workload::new("stream-eq", eager_jobs, w.nodes, w.cores_per_node),
        Policy::FcfsBackfill,
    );
    let stream = JobStream::new(Cursor::new(text.into_bytes()), TraceFormat::Swf);
    let streamed = Simulation::new(
        Workload::machine("stream-eq", w.nodes, w.cores_per_node),
        Policy::FcfsBackfill,
    )
    .with_job_stream(Box::new(stream.map(|j| j.unwrap())))
    .run(None);
    assert_eq!(eager.fingerprint(), streamed.fingerprint());
    assert_eq!(streamed.completed_count as usize, streamed.completed.len());
    assert!(
        (streamed.mean_wait_overall() - streamed.wait_stats().mean_wait).abs() < 1e-9,
        "streaming aggregates must agree with the per-job records"
    );
}

/// Bounded-memory pin for streamed ingestion: mid-run, the source never
/// buffers more than its one-job lookahead (type-level: the stream feed
/// holds an `Option<Box<Job>>`, there is no Vec to grow; this counter
/// test guards the plumbing), and dropping per-job retention keeps the
/// report's scalar aggregates.
#[test]
fn streamed_source_stays_bounded_and_completes() {
    use sst_sched::core::time::SimTime;
    use sst_sched::sim::JobSource;
    use sst_sched::trace::{JobStream, TraceFormat, Workload};
    use std::io::Cursor;
    let w = Das2Model::default().generate(3_000, 3).drop_infeasible();
    let n = w.jobs.len() as u64;
    let text = write_swf(&w.jobs, "buffer pin");
    let stream = JobStream::new(Cursor::new(text.into_bytes()), TraceFormat::Swf);
    let mut inst = Simulation::new(
        Workload::machine("buffer-pin", w.nodes, w.cores_per_node),
        Policy::Fcfs,
    )
    .with_job_stream(Box::new(stream.map(|j| j.unwrap())))
    .with_retain_completed(false)
    .build();
    let source_id = inst.engine.id_of("source").unwrap();
    let mut windows = 0u64;
    while let Some(t) = inst.next_time() {
        inst.run_window(SimTime(t.ticks() + 1_000));
        windows += 1;
        let src = inst.engine.get::<JobSource>(source_id).unwrap();
        assert!(
            src.buffered() <= 1,
            "streamed source buffered {} jobs mid-run (window {windows})",
            src.buffered()
        );
    }
    let src = inst.engine.get::<JobSource>(source_id).unwrap();
    assert_eq!(src.emitted(), n, "source must emit the whole stream");
    let rep = inst.finalize();
    assert_eq!(rep.completed_count, n, "streamed run lost jobs");
    assert!(rep.completed.is_empty(), "retention off must drop per-job records");
    assert!(rep.mean_wait_overall() >= 0.0);
}

/// Auto-horizon (`planning.horizon = "auto"`): deterministic, complete,
/// and within 5% of exact planning on the SDSC-SP2 synthetic — the
/// acceptance criterion. Shallow queues plan exactly (identical to
/// `Horizon::Exact` by construction); the burst part below forces the
/// clamp on and pins completion + determinism under it.
#[test]
fn auto_horizon_tracks_exact_planning_quality() {
    use sst_sched::sim::Horizon;
    let w = SdscSp2Model::default().generate(3_000, 19).scale_arrivals(0.75).drop_infeasible();
    let n = w.jobs.len();
    let run = |h: Horizon| {
        Simulation::new(w.clone(), Policy::FcfsBackfill).with_horizon(h).run(None)
    };
    let exact = run(Horizon::Exact);
    let auto1 = run(Horizon::Auto);
    let auto2 = run(Horizon::Auto);
    assert_eq!(auto1.completed.len(), n, "auto-horizon run lost jobs");
    assert_eq!(auto1.fingerprint(), auto2.fingerprint(), "auto-horizon not deterministic");
    let (me, ma) = (exact.wait_stats().mean_wait, auto1.wait_stats().mean_wait);
    assert!(
        (ma - me).abs() <= 0.05 * me.max(1.0),
        "auto-horizon mean wait {ma} drifts more than 5% from exact {me}"
    );

    // Deep-queue burst: everything submitted in a 50-tick window forces
    // the queue past the shallow threshold, so the derived clamp is
    // actually in force — the run must still complete everything and
    // reproduce byte-identically.
    let burst_jobs: Vec<sst_sched::job::Job> = w
        .jobs
        .iter()
        .take(1_500)
        .map(|j| {
            let mut b = j.clone();
            b.submit = sst_sched::core::time::SimTime(j.submit.ticks() % 50);
            b
        })
        .collect();
    let burst = sst_sched::trace::Workload::new("burst", burst_jobs, w.nodes, w.cores_per_node);
    let m = burst.jobs.len();
    let b1 = Simulation::new(burst.clone(), Policy::FcfsBackfill)
        .with_horizon(Horizon::Auto)
        .run(None);
    let b2 = Simulation::new(burst, Policy::FcfsBackfill)
        .with_horizon(Horizon::Auto)
        .run(None);
    assert_eq!(b1.completed.len(), m, "deep-queue auto-horizon run lost jobs");
    assert_eq!(b1.fingerprint(), b2.fingerprint(), "deep-queue auto run not reproducible");
}

/// `planning.auto_*` knobs actually steer the auto-horizon law: default
/// params are byte-identical to the constants they replaced, and a
/// deliberately tiny shallow-queue threshold flips a shallow run onto
/// the clamped path while staying complete and deterministic.
#[test]
fn auto_horizon_params_default_identical_and_override_effective() {
    use sst_sched::sim::{AutoHorizonParams, Horizon};
    let w = SdscSp2Model::default().generate(1_200, 29).scale_arrivals(0.7).drop_infeasible();
    let n = w.jobs.len();
    let run = |params: Option<AutoHorizonParams>| {
        let mut sim =
            Simulation::new(w.clone(), Policy::FcfsBackfill).with_horizon(Horizon::Auto);
        if let Some(p) = params {
            sim = sim.with_auto_horizon_params(p);
        }
        sim.run(None)
    };
    // Explicit defaults == implicit defaults, bit for bit.
    assert_eq!(
        run(None).fingerprint(),
        run(Some(AutoHorizonParams::default())).fingerprint(),
        "explicit default auto params changed a run"
    );
    // A tiny shallow threshold + floor forces the clamp on where the
    // defaults would plan exactly; the run must survive it.
    let tight = AutoHorizonParams { shallow_queue: 4, estimates: 4, min_horizon: 60 };
    let a = run(Some(tight));
    assert_eq!(a.completed.len(), n, "tight auto params lost jobs");
    assert_eq!(a.fingerprint(), run(Some(tight)).fingerprint(), "tight params not reproducible");
}

/// Streamed fault runs without `faults.until`: the injector horizon is
/// derived from the stream's last-seen submission (+ 4 x mttr), so
/// failures are actually injected, the run completes and repeated runs
/// are byte-identical — and an eager run of the same trace with the
/// equivalent explicit `until` sees the same failure pressure.
#[test]
fn streamed_fault_run_derives_injector_horizon() {
    use sst_sched::trace::{JobStream, TraceFormat, Workload};
    use std::io::Cursor;
    let w = SdscSp2Model::default().generate(1_000, 17).drop_infeasible();
    let text = write_swf(&w.jobs, "streamed faults");
    let faults =
        FaultConfig { mtbf: 20_000.0, mttr: 2_000.0, seed: 33, ..FaultConfig::default() };
    assert!(faults.until.is_none(), "this test exercises the derived horizon");
    let streamed = || {
        let stream =
            JobStream::new(Cursor::new(text.clone().into_bytes()), TraceFormat::Swf);
        let machine = Workload::machine("streamed-faults", w.nodes, w.cores_per_node);
        Simulation::new(machine, Policy::FcfsBackfill)
            .with_job_stream(Box::new(stream.map(|j| j.unwrap())))
            .with_faults(faults)
            .run(None)
    };
    let a = streamed();
    assert_eq!(a.completed_count as usize, w.jobs.len(), "streamed fault run lost jobs");
    assert!(
        a.faults.failures > 0,
        "derived horizon must let the injector fire (failures = 0)"
    );
    assert_eq!(a.faults.failures, a.faults.repairs, "every failure must repair");
    let b = streamed();
    assert_eq!(a.fingerprint(), b.fingerprint(), "derived-horizon run not reproducible");
    // The derived bound is max(stream last-seen submission, last engine
    // activity) + 4 x mttr, so the streamed chain never stops before the
    // pure stream law's endpoint — it may only extend past it while the
    // machine is still draining queued work. Bracket it between the two
    // eager laws.
    let last_submit = w.jobs.iter().map(|j| j.submit.ticks()).max().unwrap();
    let eager_floor = Simulation::new(w.clone(), Policy::FcfsBackfill)
        .with_faults(FaultConfig { until: Some(last_submit + 8_000), ..faults })
        .run(None);
    assert!(eager_floor.faults.failures > 0);
    assert!(
        a.faults.failures >= eager_floor.faults.failures,
        "streamed ({}) must not stop before the stream law's bound ({})",
        a.faults.failures,
        eager_floor.faults.failures
    );
    // And the activity mark can never outlive the run itself, so the
    // run's own end time + 4 x mttr caps the injected chain.
    let eager_ceil = Simulation::new(w.clone(), Policy::FcfsBackfill)
        .with_faults(FaultConfig { until: Some(a.end_time.ticks() + 8_000), ..faults })
        .run(None);
    assert!(
        a.faults.failures <= eager_ceil.faults.failures,
        "streamed ({}) must not inject past its own activity bound ({})",
        a.faults.failures,
        eager_ceil.faults.failures
    );
}

/// Regression test for the mid-trace arrival-drought bug: a streamed
/// fault run whose trace has a gap longer than 4 x mttr between bursts,
/// followed by a tail of queued work that drains long after the last
/// submission. Under the old law (stream watermark alone) the injector
/// horizon froze at `last submit + 4 x mttr` and injection ended while
/// the machine was still full; the fixed law tracks engine activity, so
/// failures keep landing until the queue actually drains.
#[test]
fn streamed_drought_keeps_injecting_while_machine_drains() {
    use sst_sched::core::time::SimTime;
    use sst_sched::job::Job;
    use sst_sched::trace::Workload;
    let mut jobs = Vec::new();
    // Burst 1: ten half-machine jobs in the first ten ticks (~2000 ticks
    // of work on the 2x2-core machine).
    for i in 0..10u64 {
        jobs.push(Job::simple(i, i, 2, 400));
    }
    // Drought: nothing arrives until t = 5000 — far beyond 4 x mttr.
    // Burst 2: forty whole-machine jobs; the queue drains serially until
    // roughly t = 13_000, long past the last submission at t = 5039.
    for i in 0..40u64 {
        jobs.push(Job::simple(100 + i, 5_000 + i, 4, 200));
    }
    let n = jobs.len();
    let faults =
        FaultConfig { mtbf: 500.0, mttr: 50.0, seed: 7, ..FaultConfig::default() };
    let dynamic = || {
        Simulation::new(Workload::machine("drought", 2, 2), Policy::Fcfs)
            .with_job_stream(Box::new(jobs.clone().into_iter()))
            .with_faults(faults)
            .run(None)
    };
    let a = dynamic();
    assert_eq!(a.completed_count as usize, n, "drought run lost jobs");
    assert_eq!(a.faults.failures, a.faults.repairs);
    assert_eq!(a.fingerprint(), dynamic().fingerprint(), "drought run not reproducible");
    // Old law's endpoint: last submission (5039) + 4 x mttr (200). The
    // eager run with that explicit horizon models the buggy behaviour.
    let old_law = Simulation::new(
        Workload::new("drought-eager", jobs.clone(), 2, 2),
        Policy::Fcfs,
    )
    .with_faults(FaultConfig { until: Some(5_039 + 200), ..faults })
    .run(None);
    assert_eq!(old_law.completed.len(), n);
    assert!(
        a.faults.failures > old_law.faults.failures,
        "drought fix must keep injecting through the drain: dynamic {} vs old law {}",
        a.faults.failures,
        old_law.faults.failures
    );
    // The drain runs well past the old bound, so the gap is substantial,
    // and the activity-extended chain still terminates with the run.
    assert!(a.end_time > SimTime(5_239), "tail must drain past the old bound");
}

#[test]
fn weibull_faults_run_deterministic_and_complete() {
    let w = SdscSp2Model::default().generate(500, 9).drop_infeasible();
    let n = w.jobs.len();
    let faults = FaultConfig {
        mtbf: 8_000.0,
        mttr: 2_000.0,
        seed: 31,
        distribution: sst_sched::sim::FaultDistribution::Weibull,
        shape: 0.7,
        ..FaultConfig::default()
    };
    let preemption = PreemptionConfig {
        mode: PreemptionMode::Checkpoint,
        checkpoint_overhead: SimDuration(30),
        restart_overhead: SimDuration(30),
        starvation_threshold: SimDuration(0),
    };
    let run = |w: sst_sched::trace::Workload| {
        Simulation::new(w, Policy::FcfsBackfill)
            .with_faults(faults)
            .with_preemption(preemption)
            .run(None)
    };
    let a = run(w.clone());
    assert_eq!(a.completed.len(), n);
    assert!(a.faults.failures > 0, "weibull trace injected nothing");
    assert_eq!(a.fingerprint(), run(w.clone()).fingerprint());
    // A different shape changes the failure trace.
    let other = Simulation::new(w, Policy::FcfsBackfill)
        .with_faults(FaultConfig { shape: 3.0, ..faults })
        .with_preemption(preemption)
        .run(None);
    assert_ne!(a.fingerprint(), other.fingerprint(), "shape knob must matter");
}

#[test]
fn cli_run_with_faults_reports_subsystem() {
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let out = std::process::Command::new(exe)
        .args([
            "run", "--workload", "das2", "--jobs", "400", "--policy", "fcfs-backfill",
            "--mtbf", "8000", "--mttr", "2000", "--faults-seed", "5",
            "--preemption", "checkpoint", "--ckpt-overhead", "30", "--restart-overhead", "30",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("preemption mode   checkpoint"), "{text}");
    assert!(text.contains("node failures"), "{text}");
    assert!(text.contains("effective util"), "{text}");
}

#[test]
fn cli_binary_help_and_policies() {
    // The binary is built by the test harness's dependency graph only in
    // some cargo invocations; fall back to skipping when absent.
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let out = std::process::Command::new(exe).arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("USAGE"));

    let out = std::process::Command::new(exe).arg("policies").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for p in ["fcfs", "sjf", "ljf", "fcfs-bestfit", "fcfs-backfill", "cons-backfill"] {
        assert!(text.contains(p), "policies output missing {p}");
    }
}

#[test]
fn cli_run_and_trace_info() {
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let out = std::process::Command::new(exe)
        .args(["run", "--workload", "das2", "--jobs", "300", "--policy", "fcfs"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("jobs completed    300"), "{text}");

    let out = std::process::Command::new(exe)
        .args(["trace-info", "--workload", "sdsc-sp2", "--jobs", "500"])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("128 nodes"));
}

#[test]
fn cli_order_and_memory_flags() {
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let out = std::process::Command::new(exe)
        .args([
            "run", "--workload", "sdsc-sp2", "--jobs", "300", "--policy", "cons-backfill",
            "--order", "fair-share", "--half-life", "7200",
            "--mem", "4096", "--memory-aware",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("queue order       fair-share"), "{text}");
    assert!(text.contains("fair-share users"), "{text}");

    // Unknown order values fail loudly and name the valid set.
    let out = std::process::Command::new(exe)
        .args(["run", "--workload", "das2", "--jobs", "10", "--order", "random"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("fair-share"));
}

#[test]
fn cli_streamed_trace_run() {
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let w = Das2Model::default().generate(200, 6).drop_infeasible();
    let n = w.jobs.len();
    let text = write_swf(&w.jobs, "cli stream test");
    let path = std::env::temp_dir().join("sst_sched_cli_stream_test.swf");
    std::fs::write(&path, text).unwrap();
    let out = std::process::Command::new(exe)
        .args([
            "run", "--trace", path.to_str().unwrap(), "--stream", "--policy", "fcfs",
            "--nodes", &w.nodes.to_string(), "--cores", &w.cores_per_node.to_string(),
        ])
        .output()
        .unwrap();
    let _ = std::fs::remove_file(&path);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("streamed onto"), "{text}");
    assert!(text.contains(&format!("jobs completed    {n}")), "{text}");

    // --stream without --trace must fail loudly.
    let out = std::process::Command::new(exe)
        .args(["run", "--workload", "das2", "--jobs", "10", "--stream"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--trace"));
}

#[test]
fn cli_rejects_unknown_options() {
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let out = std::process::Command::new(exe)
        .args(["run", "--jbs", "300"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("jbs"));
}

#[test]
fn cli_workflow_spec() {
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let out = std::process::Command::new(exe)
        .args(["workflow", "--spec", "examples/workflows/listing2.json"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("makespan     600 s"), "{text}");
}

/// The ingestion tier is invisible to the simulation: one SP2 trace run
/// through (1) the scalar SWF parser, (2) the zero-copy byte scanner on
/// the same text, (3) the converted binary stf eagerly, and (4) the stf
/// stream feeding `with_job_stream`, produces one identical run
/// fingerprint — format and parser are pure I/O choices, never
/// semantics.
#[test]
fn stf_run_matches_swf_run_bit_for_bit() {
    use sst_sched::trace::{stf, FastTrace, TraceFormat, Workload};
    let w = SdscSp2Model::default().generate(2_000, 23).drop_infeasible();
    let text = write_swf(&w.jobs, "cross-format determinism");
    let dir = std::env::temp_dir();
    let swf_path = dir.join("sst_sched_xformat.swf");
    let stf_path = dir.join("sst_sched_xformat.stf");
    std::fs::write(&swf_path, &text).unwrap();
    let stats =
        stf::convert_trace_file(swf_path.to_str().unwrap(), stf_path.to_str().unwrap()).unwrap();

    let run = |jobs: Vec<sst_sched::job::Job>| {
        run_policy(
            Workload::new("xformat", jobs, w.nodes, w.cores_per_node),
            Policy::FcfsBackfill,
        )
        .fingerprint()
    };
    // (1) scalar text parse.
    let scalar_jobs = parse_swf(&text).unwrap();
    assert_eq!(stats.records as usize, scalar_jobs.len());
    let scalar_fp = run(scalar_jobs);
    // (2) byte scanner over the same text.
    let fast_fp = run(FastTrace::open(swf_path.to_str().unwrap()).unwrap().parse().unwrap());
    // (3) binary stf, eager.
    let stf_trace = FastTrace::open(stf_path.to_str().unwrap()).unwrap();
    assert_eq!(stf_trace.format(), TraceFormat::Stf);
    let stf_fp = run(stf_trace.parse().unwrap());
    // (4) binary stf, streamed into the simulator.
    let stream = FastTrace::open(stf_path.to_str().unwrap()).unwrap().into_stream();
    let streamed_fp = Simulation::new(
        Workload::machine("xformat", w.nodes, w.cores_per_node),
        Policy::FcfsBackfill,
    )
    .with_job_stream(Box::new(stream.map(|j| j.unwrap())))
    .run(None)
    .fingerprint();
    let _ = std::fs::remove_file(&swf_path);
    let _ = std::fs::remove_file(&stf_path);
    assert_eq!(scalar_fp, fast_fp, "byte scanner diverged from the scalar parser");
    assert_eq!(scalar_fp, stf_fp, "stf conversion changed the run");
    assert_eq!(scalar_fp, streamed_fp, "streamed stf diverged from the eager run");
}

/// Satellite pin: a corrupt trace fails a streamed CLI run with the
/// offending line number and byte offset in the final error.
#[test]
fn cli_streamed_error_reports_line_and_offset() {
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let good = "1 0 10 120 4 -1 -1 4 600 -1 1 12 3 -1 -1 -1 -1 -1\n";
    let body = format!("{good}1 2 3\n");
    let path = std::env::temp_dir().join("sst_sched_cli_badline.swf");
    std::fs::write(&path, &body).unwrap();
    for extra in [&["--stream"][..], &["--stream", "--fast-parse"][..]] {
        let mut args = vec!["run", "--trace", path.to_str().unwrap(), "--policy", "fcfs"];
        args.extend_from_slice(extra);
        let out = std::process::Command::new(exe).args(&args).output().unwrap();
        assert!(!out.status.success(), "corrupt trace must fail ({extra:?})");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("trace ingestion failed"), "{err}");
        assert!(
            err.contains(&format!("trace line 2 at byte offset {}", good.len())),
            "missing position in: {err}"
        );
    }
    let _ = std::fs::remove_file(&path);
}

/// CLI round-trip of the converter: convert a text trace, then run the
/// stf output and get the same completion count as the text run.
#[test]
fn cli_convert_and_run_stf() {
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let w = Das2Model::default().generate(200, 17).drop_infeasible();
    let n = w.jobs.len();
    let text = write_swf(&w.jobs, "cli convert test");
    let dir = std::env::temp_dir();
    let swf_path = dir.join("sst_sched_cli_convert.swf");
    let stf_path = dir.join("sst_sched_cli_convert.stf");
    std::fs::write(&swf_path, text).unwrap();
    let out = std::process::Command::new(exe)
        .args(["convert", swf_path.to_str().unwrap(), stf_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains(&format!("{n} records")), "{text}");
    let out = std::process::Command::new(exe)
        .args([
            "run", "--trace", stf_path.to_str().unwrap(), "--stream", "--policy", "fcfs",
            "--nodes", &w.nodes.to_string(), "--cores", &w.cores_per_node.to_string(),
        ])
        .output()
        .unwrap();
    let _ = std::fs::remove_file(&swf_path);
    let _ = std::fs::remove_file(&stf_path);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains(&format!("jobs completed    {n}")), "{text}");

    // A non-.stf output is rejected loudly.
    let out = std::process::Command::new(exe)
        .args(["convert", "in.swf", "out.swf"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains(".stf"));
}
