//! Cross-module integration tests: config -> workload -> simulation ->
//! metrics, trace round-trips through the full pipeline, and the CLI
//! binary itself.

use sst_sched::config::ExperimentConfig;
use sst_sched::sched::Policy;
use sst_sched::sim::run_policy;
use sst_sched::trace::{parse_swf, write_swf, Das2Model, SdscSp2Model};

#[test]
fn config_to_simulation_pipeline() {
    let cfg = ExperimentConfig::parse(
        r#"{
            "workload": {"kind": "sdsc-sp2", "jobs": 800, "seed": 3},
            "scheduler": {"policy": "sjf"}
        }"#,
    )
    .unwrap();
    let w = cfg.build_workload().unwrap();
    assert_eq!(w.nodes, 128);
    let r = run_policy(w, cfg.policy);
    assert_eq!(r.policy, "sjf");
    assert!(r.completed.len() >= 790); // a few rejects possible
    assert!(r.wait_stats().jobs == r.completed.len());
}

#[test]
fn swf_roundtrip_through_simulator() {
    // Generate -> write SWF -> parse SWF -> simulate both -> identical.
    let w = Das2Model::default().generate(500, 9).drop_infeasible();
    let text = write_swf(&w.jobs, "roundtrip");
    let parsed = parse_swf(&text).unwrap();
    assert_eq!(parsed.len(), w.jobs.len());
    let w2 = sst_sched::trace::Workload::new("reparsed", parsed, w.nodes, w.cores_per_node);
    let a = run_policy(w.clone(), Policy::FcfsBackfill);
    let b = run_policy(w2, Policy::FcfsBackfill);
    assert_eq!(a.events, b.events);
    assert_eq!(a.end_time, b.end_time);
    let starts = |r: &sst_sched::sim::SimReport| {
        let mut v: Vec<(u64, u64)> =
            r.completed.iter().map(|j| (j.id, j.start.unwrap().ticks())).collect();
        v.sort_unstable();
        v
    };
    assert_eq!(starts(&a), starts(&b));
}

#[test]
fn both_workload_models_run_under_all_policies() {
    for (name, w) in [
        ("das2", Das2Model::default().generate(600, 1).drop_infeasible()),
        ("sp2", SdscSp2Model::default().generate(400, 1).drop_infeasible()),
    ] {
        let n = w.jobs.len();
        for p in Policy::ALL {
            let r = run_policy(w.clone(), p);
            assert_eq!(r.completed.len(), n, "{name}/{p} lost jobs");
        }
    }
}

#[test]
fn utilization_series_is_bounded() {
    let w = SdscSp2Model::default().generate(1_000, 5).drop_infeasible();
    let r = run_policy(w, Policy::FcfsBackfill);
    for &(_, u) in r.utilization.points() {
        assert!((0.0..=1.0).contains(&u), "utilization {u} out of range");
    }
    assert!(r.mean_utilization > 0.0 && r.mean_utilization <= 1.0);
}

#[test]
fn occupancy_ends_at_zero_when_queue_drains() {
    let w = Das2Model::default().generate(800, 2).drop_infeasible();
    let r = run_policy(w, Policy::Fcfs);
    assert_eq!(r.occupancy.points().last().unwrap().1, 0.0);
    assert_eq!(r.running.points().last().unwrap().1, 0.0);
}

#[test]
fn cli_binary_help_and_policies() {
    // The binary is built by the test harness's dependency graph only in
    // some cargo invocations; fall back to skipping when absent.
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let out = std::process::Command::new(exe).arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("USAGE"));

    let out = std::process::Command::new(exe).arg("policies").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for p in ["fcfs", "sjf", "ljf", "fcfs-bestfit", "fcfs-backfill", "cons-backfill"] {
        assert!(text.contains(p), "policies output missing {p}");
    }
}

#[test]
fn cli_run_and_trace_info() {
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let out = std::process::Command::new(exe)
        .args(["run", "--workload", "das2", "--jobs", "300", "--policy", "fcfs"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("jobs completed    300"), "{text}");

    let out = std::process::Command::new(exe)
        .args(["trace-info", "--workload", "sdsc-sp2", "--jobs", "500"])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("128 nodes"));
}

#[test]
fn cli_rejects_unknown_options() {
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let out = std::process::Command::new(exe)
        .args(["run", "--jbs", "300"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("jbs"));
}

#[test]
fn cli_workflow_spec() {
    let exe = env!("CARGO_BIN_EXE_sst-sched");
    let out = std::process::Command::new(exe)
        .args(["workflow", "--spec", "examples/workflows/listing2.json"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("makespan     600 s"), "{text}");
}
