//! Integration contract of `sst-sched serve`.
//!
//! Three layers: (1) every `-> ` / `<- ` example pair in
//! `docs/PROTOCOL.md` is round-tripped verbatim through the server
//! codec, so the protocol document cannot drift from the code;
//! (2) a real Unix-socket session drives submit / predict_wait /
//! status / shutdown end to end, twice, and the two reply transcripts
//! must be identical (the daemon is deterministic); (3) property tests
//! pin the `predict_wait` guarantees — speculation never mutates the
//! live run, and in a quiet system the prediction is exact.

use sst_sched::config::{ExperimentConfig, ServeOptions};
use sst_sched::runtime::serve::{backpressure_json, ServerCore};
use sst_sched::sched::Policy;
use sst_sched::util::prop::check_n;

/// The machine the PROTOCOL.md worked session runs on.
fn protocol_cfg() -> ExperimentConfig {
    ExperimentConfig {
        nodes: Some(2),
        cores_per_node: Some(4),
        policy: Policy::Fcfs,
        ..ExperimentConfig::default()
    }
}

/// Every `-> request` / `<- reply` pair in docs/PROTOCOL.md, in order.
fn protocol_examples() -> Vec<(String, String)> {
    let text = include_str!("../../docs/PROTOCOL.md");
    let mut reqs = Vec::new();
    let mut resps = Vec::new();
    for line in text.lines() {
        if let Some(r) = line.strip_prefix("-> ") {
            reqs.push(r.to_string());
        } else if let Some(r) = line.strip_prefix("<- ") {
            resps.push(r.to_string());
        }
    }
    assert_eq!(reqs.len(), resps.len(), "PROTOCOL.md -> / <- markers unbalanced");
    assert!(reqs.len() >= 8, "PROTOCOL.md lost its worked session");
    reqs.into_iter().zip(resps).collect()
}

#[test]
fn protocol_doc_examples_round_trip_verbatim() {
    let mut core = ServerCore::new(protocol_cfg());
    for (i, (req, want)) in protocol_examples().into_iter().enumerate() {
        let got = core.handle_line(i as u64 + 1, &req).to_string();
        assert_eq!(
            got,
            want,
            "docs/PROTOCOL.md example {} drifted from the implementation\n  -> {req}",
            i + 1
        );
    }
}

#[test]
fn protocol_doc_backpressure_example_is_exact() {
    let text = include_str!("../../docs/PROTOCOL.md");
    let documented = text
        .lines()
        .find(|l| l.contains("\"code\":\"backpressure\""))
        .expect("PROTOCOL.md lost its backpressure example");
    assert_eq!(documented.trim(), backpressure_json(9, 2).to_string());
}

/// Speculative placement must be invisible: the fingerprint of the
/// live run's future is byte-identical before and after any number of
/// predict_wait requests.
#[test]
fn predict_wait_never_mutates_the_live_run() {
    check_n("serve-predict-non-perturbation", 24, |rng| {
        let mut core = ServerCore::new(ExperimentConfig {
            nodes: Some(4),
            cores_per_node: Some(8),
            ..ExperimentConfig::default()
        });
        let mut line = 0u64;
        let mut t = 0u64;
        for _ in 0..(3 + rng.below(12)) {
            t += rng.below(200);
            line += 1;
            let r = core.handle_line(
                line,
                &format!(
                    r#"{{"req":"submit","at":{t},"job":{{"cores":{},"runtime":{}}}}}"#,
                    1 + rng.below(8),
                    1 + rng.below(500)
                ),
            );
            if !r.get_bool_or("ok", false) {
                return Err(format!("submit failed: {r:?}"));
            }
        }
        let before = core.fingerprint("default")?;
        for _ in 0..3 {
            line += 1;
            let p = core.handle_line(
                line,
                &format!(
                    r#"{{"req":"predict_wait","job":{{"cores":{},"runtime":{}}}}}"#,
                    1 + rng.below(8),
                    1 + rng.below(500)
                ),
            );
            if !p.get_bool_or("ok", false) {
                return Err(format!("predict failed: {p:?}"));
            }
        }
        let after = core.fingerprint("default")?;
        if before != after {
            return Err("speculative predict_wait perturbed the live run".into());
        }
        Ok(())
    });
}

/// In an otherwise-quiet system, really submitting the job right after
/// predicting it starts the job exactly where the prediction said —
/// same id (peeked, not consumed), same start tick.
#[test]
fn predicted_start_matches_reality_in_a_quiet_system() {
    check_n("serve-predict-accuracy", 24, |rng| {
        let mut core = ServerCore::new(protocol_cfg());
        let mut line = 0u64;
        let mut t = 0u64;
        for _ in 0..(2 + rng.below(10)) {
            t += rng.below(100);
            line += 1;
            let r = core.handle_line(
                line,
                &format!(
                    r#"{{"req":"submit","at":{t},"job":{{"cores":{},"runtime":{}}}}}"#,
                    1 + rng.below(4),
                    1 + rng.below(300)
                ),
            );
            if !r.get_bool_or("ok", false) {
                return Err(format!("submit failed: {r:?}"));
            }
        }
        let job = format!(
            r#"{{"cores":{},"runtime":{}}}"#,
            1 + rng.below(4),
            1 + rng.below(300)
        );
        line += 1;
        let p = core.handle_line(line, &format!(r#"{{"req":"predict_wait","job":{job}}}"#));
        if !p.get_bool_or("ok", false) {
            return Err(format!("predict failed: {p:?}"));
        }
        let id = p.get_u64_or("job_id", 0);
        let predicted = p.get_u64_or("predicted_start", u64::MAX);
        line += 1;
        let s = core.handle_line(line, &format!(r#"{{"req":"submit","job":{job}}}"#));
        if s.get_u64_or("job_id", 0) != id {
            return Err("submit after predict did not reuse the peeked job id".into());
        }
        let fp = core.fingerprint("default")?;
        let actual: u64 = fp
            .lines()
            .find(|l| l.starts_with(&format!("{id}:")))
            .ok_or_else(|| format!("job {id} missing from fingerprint:\n{fp}"))?
            .split(':')
            .nth(1)
            .expect("fingerprint start field")
            .parse()
            .map_err(|e| format!("bad start field: {e}"))?;
        if actual != predicted {
            return Err(format!(
                "predicted start {predicted} but the real run started the job at {actual}"
            ));
        }
        Ok(())
    });
}

/// End-to-end over a real Unix domain socket: spawn the daemon, drive
/// the protocol, drain it with `shutdown`, and do it all twice — the
/// two transcripts must match byte for byte.
#[cfg(unix)]
#[test]
fn daemon_round_trips_over_a_real_socket() {
    use sst_sched::runtime::serve::serve;
    use std::io::{BufRead, BufReader, Write};
    use std::os::unix::net::UnixStream;
    use std::path::PathBuf;
    use std::time::Duration;

    fn session(path: PathBuf, lines: &[&str]) -> Vec<String> {
        let cfg = ExperimentConfig {
            serve: ServeOptions {
                socket: path.to_str().expect("utf-8 socket path").to_string(),
                ..ServeOptions::default()
            },
            ..protocol_cfg()
        };
        let daemon = std::thread::spawn(move || serve(cfg).expect("daemon failed"));
        let mut stream = None;
        for _ in 0..500 {
            match UnixStream::connect(&path) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(_) => std::thread::sleep(Duration::from_millis(10)),
            }
        }
        let mut stream = stream.expect("could not connect to the daemon socket");
        let mut reader = BufReader::new(stream.try_clone().expect("clone socket"));
        let mut replies = Vec::with_capacity(lines.len());
        for l in lines {
            writeln!(stream, "{l}").expect("write request");
            let mut buf = String::new();
            reader.read_line(&mut buf).expect("read reply");
            replies.push(buf.trim().to_string());
        }
        drop(reader);
        drop(stream);
        daemon.join().expect("daemon thread panicked");
        assert!(!path.exists(), "daemon must unlink its socket on drain");
        replies
    }

    let requests = [
        r#"{"req":"submit","job":{"cores":4,"runtime":100}}"#,
        r#"{"req":"submit","job":{"cores":4,"runtime":100}}"#,
        r#"{"req":"predict_wait","job":{"cores":4,"runtime":50}}"#,
        r#"{"req":"status"}"#,
        r#"{"req":"shutdown"}"#,
    ];
    let base = std::env::temp_dir();
    let a = session(
        base.join(format!("sst-serve-{}-a.sock", std::process::id())),
        &requests,
    );
    let b = session(
        base.join(format!("sst-serve-{}-b.sock", std::process::id())),
        &requests,
    );
    assert_eq!(a, b, "two identical daemon sessions must answer identically");
    assert!(a[0].contains(r#""job_id":1"#), "{}", a[0]);
    assert!(a[2].contains(r#""predicted_start":100"#), "{}", a[2]);
    assert!(a[3].contains(r#""running":2"#), "{}", a[3]);
    assert!(a[4].contains(r#""draining":true"#), "{}", a[4]);
}
