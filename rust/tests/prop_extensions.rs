//! Property tests for the beyond-the-paper modules: conservative
//! backfilling's reservation profile, topology metrics, multi-cluster
//! routing, and dynamic workflow scheduling.

use sst_sched::core::rng::Rng;
use sst_sched::job::Job;
use sst_sched::resources::Topology;
use sst_sched::sched::Policy;
use sst_sched::sim::{run_policy, MetaScheduler, Routing};
use sst_sched::trace::{Das2Model, Workload};
use sst_sched::util::prop::check_n;
use sst_sched::workflow::task::Task;
use sst_sched::workflow::{DynamicExecutor, TaskOrder, Workflow, WorkflowExecutor};

fn random_workload(rng: &mut Rng) -> Workload {
    let nodes = rng.range(1, 12) as usize;
    let cores = rng.range(1, 6);
    let n = rng.range(10, 80) as usize;
    let mut t = 0u64;
    let jobs: Vec<Job> = (0..n as u64)
        .map(|id| {
            t += rng.below(300);
            let runtime = rng.range(1, 3000);
            Job::with_estimate(
                id + 1,
                t,
                rng.range(1, nodes as u64 * cores + 1),
                runtime,
                runtime + rng.below(3000),
            )
        })
        .collect();
    Workload::new("ext", jobs, nodes, cores).drop_infeasible()
}

#[test]
fn conservative_never_delays_earlier_arrivals() {
    // The defining property: adding LATER jobs to the queue never makes
    // any EARLIER job start later under conservative backfilling.
    //
    // This holds for EXACT estimates (est == runtime): with over-
    // estimates the guarantee covers the *reserved* start, not the
    // realized one — early completions open gaps that backfilled jobs
    // occupy at the instant an earlier job would otherwise have grabbed
    // them (Mu'alem & Feitelson 2001 discuss exactly this).
    check_n("conservative no-delay", 60, |rng| {
        let mut w = random_workload(rng);
        for j in w.jobs.iter_mut() {
            j.est_runtime = j.runtime;
        }
        if w.jobs.len() < 4 {
            return Ok(());
        }
        let cut = w.jobs.len() / 2;
        let prefix = Workload::new("prefix", w.jobs[..cut].to_vec(), w.nodes, w.cores_per_node);
        let full = run_policy(w.clone(), Policy::ConservativeBackfill);
        let pre = run_policy(prefix, Policy::ConservativeBackfill);
        let start_of = |r: &sst_sched::sim::SimReport, id: u64| {
            r.completed.iter().find(|j| j.id == id).map(|j| j.start.unwrap())
        };
        for j in &w.jobs[..cut] {
            let (Some(a), Some(b)) = (start_of(&full, j.id), start_of(&pre, j.id)) else {
                continue;
            };
            if a > b {
                return Err(format!(
                    "job {} delayed by later arrivals: {} > {}",
                    j.id,
                    a.ticks(),
                    b.ticks()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn all_policies_complete_random_workloads() {
    check_n("six policies total", 60, |rng| {
        let w = random_workload(rng);
        let n = w.jobs.len();
        let p = Policy::ALL[rng.below(Policy::ALL.len() as u64) as usize];
        let r = run_policy(w, p);
        if r.completed.len() != n {
            return Err(format!("{p}: {} of {n} completed", r.completed.len()));
        }
        Ok(())
    });
}

#[test]
fn topology_distance_is_a_symmetric_bounded_metric() {
    check_n("topology metric", 40, |rng| {
        let topo = match rng.below(4) {
            0 => Topology::Mesh2D { x: rng.range(2, 8) as usize, y: rng.range(2, 8) as usize },
            1 => Topology::Torus2D { x: rng.range(2, 8) as usize, y: rng.range(2, 8) as usize },
            2 => Topology::FatTree { leaf: rng.range(2, 5) as usize, agg: rng.range(1, 4) as usize },
            _ => Topology::Dragonfly { a: rng.range(2, 5) as usize, p: rng.range(1, 4) as usize },
        };
        let n = topo.nodes();
        for _ in 0..50 {
            let u = rng.below(n as u64) as usize;
            let v = rng.below(n as u64) as usize;
            let d = topo.distance(u, v);
            if topo.distance(v, u) != d {
                return Err(format!("{topo:?}: asymmetric d({u},{v})"));
            }
            if u == v && d != 0 {
                return Err("self distance nonzero".into());
            }
            if u != v && d == 0 {
                return Err("distinct nodes at distance 0".into());
            }
            if d > topo.diameter() {
                return Err(format!("{topo:?}: d({u},{v})={d} exceeds diameter"));
            }
        }
        Ok(())
    });
}

#[test]
fn routing_always_respects_cluster_capacity() {
    check_n("routing capacity", 40, |rng| {
        let routing = match rng.below(3) {
            0 => Routing::RoundRobin,
            1 => Routing::LeastLoaded,
            _ => Routing::BestFitCluster,
        };
        let m = MetaScheduler::das2_federation(routing, Policy::Fcfs);
        let jobs = Das2Model::default().generate(rng.range(50, 400) as usize, rng.next_u64()).jobs;
        for (j, r) in jobs.iter().zip(m.route(&jobs)) {
            if let Some(i) = r {
                if j.cores > m.clusters[i].total_cores() {
                    return Err(format!("job {} routed over capacity", j.id));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn dynamic_orders_agree_with_static_on_dependency_safety() {
    check_n("dynamic dep safety", 50, |rng| {
        // Random layered DAG (same construction as prop_dag).
        let layers = rng.range(2, 5) as usize;
        let mut tasks: Vec<Task> = Vec::new();
        let mut prev: Vec<u64> = Vec::new();
        let mut next_id = 1u64;
        for _ in 0..layers {
            let width = rng.range(1, 6) as usize;
            let mut this = Vec::new();
            for _ in 0..width {
                let deps: Vec<u64> =
                    prev.iter().copied().filter(|_| rng.chance(0.5)).collect();
                tasks.push(Task::new(next_id, rng.range(1, 200), 1, 0).with_deps(deps));
                this.push(next_id);
                next_id += 1;
            }
            prev = this;
        }
        let w = Workflow::new(1, "dyn", tasks).expect("layered is acyclic");
        let order = match rng.below(3) {
            0 => TaskOrder::Fcfs,
            1 => TaskOrder::CriticalPath,
            _ => TaskOrder::WidestFirst,
        };
        let mut ex = DynamicExecutor::new(rng.range(1, 6), order);
        if rng.chance(0.5) {
            ex = ex.with_preemption();
        }
        let rep = ex.run(w.clone());
        if rep.tasks.len() != w.len() {
            return Err("dynamic executor lost tasks".into());
        }
        let by: std::collections::BTreeMap<_, _> =
            rep.tasks.iter().map(|t| (t.id, *t)).collect();
        for id in w.dag.nodes() {
            for &c in w.dag.children(id) {
                if by[&c].start < by[&id].end {
                    return Err(format!("edge {id}->{c} violated under {order:?}"));
                }
            }
        }
        // Makespan bounded by critical path and serial work.
        let ms = rep.makespan.as_f64();
        if ms + 1e-9 < w.critical_path_time() {
            return Err("below critical path".into());
        }
        Ok(())
    });
}

#[test]
fn static_and_dynamic_fcfs_agree() {
    check_n("static==dynamic fcfs", 40, |rng| {
        let mut tasks = Vec::new();
        for id in 1..=rng.range(3, 20) {
            let deps = if id > 1 && rng.chance(0.4) {
                vec![rng.range(1, id - 1)]
            } else {
                vec![]
            };
            tasks.push(Task::new(id, rng.range(1, 100), 1, 0).with_deps(deps));
        }
        let Ok(w) = Workflow::new(1, "cmp", tasks) else {
            return Ok(()); // improbable duplicate-free failure guard
        };
        let cpu = rng.range(1, 5);
        let a = WorkflowExecutor::new(cpu, u64::MAX).run(w.clone());
        let b = DynamicExecutor::new(cpu, TaskOrder::Fcfs).run(w);
        if a.makespan != b.makespan {
            return Err(format!(
                "makespans differ: static {} dynamic {}",
                a.makespan.ticks(),
                b.makespan.ticks()
            ));
        }
        Ok(())
    });
}
