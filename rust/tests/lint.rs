//! Repository determinism lint — the blocking gate from the determinism
//! sentinel PR. Scans every file under `src/` with the self-contained
//! analyzer in `sst_sched::analysis::lint` and fails if any hazard is
//! neither fixed nor carrying an explicit
//! `// lint:allow(<rule-id>, <reason>)` escape. Unused or malformed
//! escapes fail too, so the allow inventory can never rot.
//!
//! Run it alone with `cargo test --test lint`.

use sst_sched::analysis::lint::{run_repo_lint, RULES};

#[test]
fn repo_is_lint_clean() {
    let findings = run_repo_lint();
    if !findings.is_empty() {
        let mut report = String::new();
        for f in &findings {
            report.push_str(&format!("{f}\n"));
        }
        panic!(
            "determinism lint found {} violation(s):\n{report}\n\
             Fix the hazard or annotate it with \
             `// lint:allow(<rule-id>, <reason>)` on (or above) the line.",
            findings.len()
        );
    }
}

#[test]
fn every_rule_is_documented() {
    assert!(!RULES.is_empty());
    for r in RULES {
        assert!(!r.id.is_empty(), "rule missing id");
        assert!(
            r.doc.len() > 20,
            "rule {} needs a real doc string, got {:?}",
            r.id,
            r.doc
        );
    }
}
