//! Figure-shape regression tests: fast versions of every paper figure,
//! asserting the qualitative results the paper reports (who wins, what
//! tracks what, what scales) so refactors cannot silently break the
//! reproduction — plus golden-figure smoke tests that lock seeded
//! summary statistics byte-exactly.

use sst_sched::harness::*;
use sst_sched::sched::Policy;
use sst_sched::sim::{SimReport, Simulation};
use sst_sched::trace::{Das2Model, SdscSp2Model};

#[test]
fn fig3a_occupancy_tracks_baseline() {
    let v = fig3a(3_000, 1, 24);
    assert!(v.correlation > 0.9, "Fig 3(a) corr {}", v.correlation);
    assert!(v.nmae < 0.15, "Fig 3(a) nmae {}", v.nmae);
    // The series is not trivial (machine actually gets used).
    assert!(v.ours.iter().cloned().fold(0.0, f64::max) > 10.0);
}

#[test]
fn fig3b_running_jobs_tracks_baseline() {
    let v = fig3b(3_000, 1, 24);
    assert!(v.correlation > 0.9, "Fig 3(b) corr {}", v.correlation);
}

#[test]
fn fig4a_wait_times_track_baseline() {
    let v = fig4a(3_000, 1, 12);
    assert!(v.ours.iter().sum::<f64>() > 0.0, "no waits formed");
    assert!(v.correlation > 0.9, "Fig 4(a) corr {}", v.correlation);
}

#[test]
fn fig4b_policy_ordering_matches_paper() {
    let rows = fig4b(4_000, 1);
    assert_eq!(rows.len(), sst_sched::sched::Policy::ALL.len());
    let by = |n: &str| rows.iter().find(|r| r.policy == n).unwrap().clone();
    // Paper Fig 4(b) qualitative claims:
    // backfilling "maximizes resource utilization by intelligently
    // filling scheduling gaps" -> at least as good as FCFS on wait.
    assert!(by("fcfs-backfill").mean_wait <= by("fcfs").mean_wait + 1e-9);
    // "SJF reduces average job completion times".
    assert!(by("sjf").mean_wait <= by("fcfs").mean_wait + 1e-9);
    // "LJF is less efficient".
    assert!(by("ljf").mean_wait >= by("sjf").mean_wait);
    // Best Fit "does not significantly improve job completion times":
    // within 10% of FCFS.
    let (bf, fc) = (by("fcfs-bestfit").mean_wait, by("fcfs").mean_wait);
    assert!((bf - fc).abs() <= 0.1 * fc.max(1.0), "bestfit {bf} vs fcfs {fc}");
    // Conservative backfilling sits between FCFS and EASY on mean wait.
    let cons = by("cons-backfill").mean_wait;
    assert!(cons <= fc + 1e-9, "conservative {cons} worse than FCFS {fc}");
    assert!(
        cons + 1e-9 >= by("fcfs-backfill").mean_wait * 0.8,
        "conservative should rarely beat EASY by much"
    );
}

#[test]
fn fig5a_speedup_grows_with_ranks_and_scale() {
    let rows = fig5(false, &[5_000, 40_000], &[1, 2, 4], 1);
    let at = |jobs: usize, ranks: usize| {
        rows.iter().find(|r| r.jobs == jobs && r.ranks == ranks).unwrap().speedup
    };
    assert!(at(40_000, 4) > 1.2, "no speedup at 4 ranks: {}", at(40_000, 4));
    assert!(at(40_000, 4) >= at(40_000, 2) * 0.75, "speedup collapsed at 4 ranks");
    // Paper: "as the job sizes increased, we achieve greater speedup".
    assert!(
        at(40_000, 4) >= at(5_000, 4) * 0.7,
        "large scale {} should not scale worse than small {}",
        at(40_000, 4),
        at(5_000, 4)
    );
}

#[test]
fn fig5b_sp2_scales() {
    let rows = fig5(true, &[20_000], &[1, 4], 1);
    assert!(rows[1].speedup > 1.2, "SP2 speedup {}", rows[1].speedup);
}

#[test]
fn fig6_workflow_scales() {
    let rows = fig6_wide(17, 128, &[1, 4], 1);
    assert!(rows[1].speedup > 1.3, "workflow speedup {}", rows[1].speedup);
    assert_eq!(rows[0].jobs, rows[1].jobs);
}

// ---------------------------------------------------------------------
// Golden-figure smoke tests: seeded scenarios whose summary statistics
// are locked into tests/golden/*.txt so perf refactors cannot silently
// change simulation results. On a checkout without the golden file the
// test blesses it (and still verifies the scenario is internally
// reproducible); commit the blessed files to pin the numbers. After an
// *intentional* semantic change, re-bless with `BLESS=1 cargo test`.
// ---------------------------------------------------------------------

/// Compact, byte-exact summary: headline stats in decimal plus IEEE bit
/// patterns, and the job-level fingerprint hash.
fn summarize(r: &SimReport) -> String {
    let s = r.wait_stats();
    let fp = sst_sched::parallel::fnv1a(r.fingerprint().as_bytes());
    format!(
        "policy={} order={} workload={}\n\
         completed={} rejected={} events={} dispatches={}\n\
         mean_wait={:.6} bits={:016x}\n\
         median_wait={:.6} bits={:016x}\n\
         p95_wait={:.6} bits={:016x}\n\
         mean_utilization={:.6} bits={:016x}\n\
         effective_utilization={:.6} bits={:016x}\n\
         makespan={} end_time={}\n\
         failures={} repairs={} preemptions={} requeues={}\n\
         lost_work_bits={:016x} overhead_work_bits={:016x}\n\
         job_fingerprint={:016x}\n",
        r.policy,
        r.order,
        r.workload,
        r.completed.len(),
        r.rejected,
        r.events,
        r.dispatches,
        s.mean_wait,
        s.mean_wait.to_bits(),
        s.median_wait,
        s.median_wait.to_bits(),
        s.p95_wait,
        s.p95_wait.to_bits(),
        r.mean_utilization,
        r.mean_utilization.to_bits(),
        r.mean_effective_utilization,
        r.mean_effective_utilization.to_bits(),
        r.makespan().ticks(),
        r.end_time.ticks(),
        r.faults.failures,
        r.faults.repairs,
        r.faults.preemptions,
        r.faults.requeues,
        r.lost_work.to_bits(),
        r.overhead_work.to_bits(),
        fp,
    )
}

fn golden_check(name: &str, summary: &str) {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden");
    let path = dir.join(format!("{name}.txt"));
    if !path.exists() || std::env::var("BLESS").is_ok() {
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(&path, summary).unwrap();
        eprintln!("golden: blessed {}", path.display());
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        summary,
        want.as_str(),
        "golden mismatch for {name}: simulation results changed.\n\
         If intentional, re-bless with `BLESS=1 cargo test --test figures`."
    );
}

fn golden_sdsc_sp2() -> SimReport {
    let w = SdscSp2Model::default().generate(1_200, 7).drop_infeasible();
    Simulation::new(w, Policy::FcfsBackfill).with_seed(7).run(None)
}

fn golden_das2_faulty() -> SimReport {
    use sst_sched::core::time::SimDuration;
    use sst_sched::sched::{PreemptionConfig, PreemptionMode};
    use sst_sched::sim::FaultConfig;
    let w = Das2Model::default().generate(1_500, 7).scale_arrivals(0.45).drop_infeasible();
    Simulation::new(w, Policy::FcfsBackfill)
        .with_seed(7)
        .with_faults(FaultConfig { mtbf: 9_000.0, mttr: 2_500.0, seed: 7, ..FaultConfig::default() })
        .with_preemption(PreemptionConfig {
            mode: PreemptionMode::Checkpoint,
            checkpoint_overhead: SimDuration(60),
            restart_overhead: SimDuration(30),
            starvation_threshold: SimDuration(0),
        })
        .run(None)
}

#[test]
fn golden_sdsc_sp2_summary_locked() {
    let a = summarize(&golden_sdsc_sp2());
    let b = summarize(&golden_sdsc_sp2());
    assert_eq!(a, b, "SDSC-SP2 golden scenario not even run-to-run reproducible");
    golden_check("sdsc_sp2_backfill", &a);
}

#[test]
fn golden_das2_fault_summary_locked() {
    let a = summarize(&golden_das2_faulty());
    let b = summarize(&golden_das2_faulty());
    assert_eq!(a, b, "DAS-2 fault golden scenario not even run-to-run reproducible");
    golden_check("das2_faulty_backfill_ckpt", &a);
}

/// Fair-share golden scenario (queue-ordering seam): EASY backfilling
/// dispatching under usage-decayed fair share on a contended SP2-like
/// workload. Bless-on-first-run like the others; the blessed file pins
/// both the ordering determinism and the usage-accounting stream.
fn golden_fairshare() -> SimReport {
    use sst_sched::sched::OrderKind;
    let w = SdscSp2Model::default().generate(1_200, 11).scale_arrivals(0.5).drop_infeasible();
    Simulation::new(w, Policy::FcfsBackfill)
        .with_seed(11)
        .with_order(OrderKind::FairShare)
        .with_fairshare_half_life(14_400)
        .run(None)
}

#[test]
fn golden_fairshare_summary_locked() {
    let r = golden_fairshare();
    assert_eq!(r.order, "fair-share");
    assert!(!r.user_shares.is_empty(), "fair share must have charged usage");
    let a = summarize(&r);
    let b = summarize(&golden_fairshare());
    assert_eq!(a, b, "fair-share golden scenario not even run-to-run reproducible");
    golden_check("sdsc_sp2_fairshare_backfill", &a);
}

/// Fault + reservation SDSC-SP2 scenario pinning the DES core's event
/// order end to end (ladder-event-queue PR): the failure/repair chain,
/// a claimed-and-expired reservation window and checkpoint preemption
/// exercise every event priority class at shared timestamps, so the
/// summary (which folds in the full per-job fingerprint) is
/// byte-identical iff the ladder queue pops the exact
/// `(time, priority, seq)` order the heap-based seed engine popped.
fn golden_sp2_faults_resv() -> SimReport {
    use sst_sched::core::time::SimDuration;
    use sst_sched::sched::{PreemptionConfig, PreemptionMode};
    use sst_sched::sim::{FaultConfig, ReservationSpec};
    let w = SdscSp2Model::default().generate(1_000, 23).scale_arrivals(0.6).drop_infeasible();
    Simulation::new(w, Policy::FcfsBackfill)
        .with_seed(23)
        .with_faults(FaultConfig {
            mtbf: 15_000.0,
            mttr: 2_000.0,
            seed: 23,
            ..FaultConfig::default()
        })
        .with_preemption(PreemptionConfig {
            mode: PreemptionMode::Checkpoint,
            checkpoint_overhead: SimDuration(60),
            restart_overhead: SimDuration(30),
            starvation_threshold: SimDuration(0),
        })
        .with_reservations(vec![ReservationSpec { start: 40_000, duration: 20_000, nodes: 16 }])
        .run(None)
}

#[test]
fn golden_sp2_fault_reservation_fingerprint_locked() {
    let r = golden_sp2_faults_resv();
    assert!(r.faults.failures > 0, "scenario must actually inject failures");
    assert!(r.faults.reservations_started >= 1, "reservation must come due");
    let a = summarize(&r);
    let b = summarize(&golden_sp2_faults_resv());
    assert_eq!(a, b, "fault+reservation scenario not even run-to-run reproducible");
    golden_check("sdsc_sp2_faults_resv_fingerprint", &a);
}

#[test]
fn fig7_sipht_waits_match_reference() {
    let v = fig7(4, 8, 1);
    let ratio = v.ours_makespan as f64 / v.ref_makespan as f64;
    assert!((0.7..1.3).contains(&ratio), "Fig 7 makespan ratio {ratio}");
    // Per-stage waits correlate: stages that wait in the reference wait
    // in ours.
    let r: Vec<f64> = v.rows.iter().map(|x| x.ref_wait).collect();
    let o: Vec<f64> = v.rows.iter().map(|x| x.ours_wait).collect();
    let corr = sst_sched::metrics::correlation(&o, &r);
    assert!(corr > 0.8, "Fig 7 stage-wait corr {corr}");
}
