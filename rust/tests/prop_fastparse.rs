//! Differential property suite for the zero-copy ingestion path
//! (`trace::fast`) and the binary stf format — the parity proof the
//! fast path ships with (the estuary/flow simd-doc idiom: a byte-level
//! scanner is only trusted because a scalar oracle checks it on
//! adversarial generated inputs).
//!
//! Three contracts:
//! 1. **fast == scalar** on generated SWF/GWF bodies — same records,
//!    same order, same field values — across CRLF endings, tab/multi-
//!    space separators, leading/trailing whitespace, `-1` sentinels,
//!    interleaved comments and blanks, fractional and exponent floats,
//!    overlong-but-valid numerics, and a truncated (newline-less)
//!    final line.
//! 2. **identical error positions** on injected corruption: the fast
//!    stream's first error carries the same line number and byte
//!    offset the scalar `JobStream` reports, string-for-string, and
//!    the eager parser's message is embedded in both.
//! 3. **stf write → read is identity** on every trace-carried field.

use sst_sched::core::rng::Rng;
use sst_sched::core::time::{SimDuration, SimTime};
use sst_sched::job::Job;
use sst_sched::trace::{parse_gwf, parse_swf, stf, FastTrace, JobStream, TraceFormat};
use sst_sched::util::prop::check_n;
use std::io::Cursor;

fn jobs_equal(a: &Job, b: &Job) -> bool {
    a.id == b.id
        && a.submit == b.submit
        && a.cores == b.cores
        && a.memory_mb == b.memory_mb
        && a.est_runtime == b.est_runtime
        && a.runtime == b.runtime
        && a.user == b.user
        && a.group == b.group
}

/// Random inter-field separator: single/double space, tab, tab+space.
fn sep(rng: &mut Rng) -> &'static str {
    match rng.below(4) {
        0 => " ",
        1 => "\t",
        2 => "  ",
        _ => " \t",
    }
}

fn sentinel_or(rng: &mut Rng, val: u64) -> String {
    if rng.below(4) == 0 {
        "-1".to_string()
    } else {
        val.to_string()
    }
}

/// A GWF float with randomized spelling: integer, `.0`, `.5`, `e0`,
/// explicit `+`, or a 16-digit integer (past the fast path's 15-digit
/// cutoff, forcing the `str::parse` fallback).
fn gwf_num(rng: &mut Rng, val: u64) -> String {
    match rng.below(6) {
        0 => format!("{val}.0"),
        1 => format!("{val}.5"),
        2 => format!("{val}e0"),
        3 => format!("+{val}"),
        4 => format!("100000000000000{}", rng.below(10)),
        _ => val.to_string(),
    }
}

/// One record line with adversarial separators and sentinels. Valid
/// (parses or is skipped as cancelled) — corruption is injected
/// separately.
fn gen_record(rng: &mut Rng, format: TraceFormat, id: u64, submit: u64) -> String {
    let run = if rng.below(8) == 0 { 0 } else { 1 + rng.below(5_000) };
    let used = if rng.below(8) == 0 { 0 } else { 1 + rng.below(64) };
    let req_procs = sentinel_or(rng, 1 + rng.below(64));
    let req_time = sentinel_or(rng, 1 + rng.below(9_000));
    let req_mem = sentinel_or(rng, 128 + rng.below(4_096));
    let user = rng.below(50);
    let group = rng.below(8);
    let fields: Vec<String> = match format {
        TraceFormat::Swf => {
            // Occasionally an 18-digit submit (still a valid i64).
            let submit = if rng.below(16) == 0 {
                format!("10000000000000000{}", rng.below(10))
            } else {
                submit.to_string()
            };
            vec![
                id.to_string(),
                submit,
                "-1".into(),
                run.to_string(),
                used.to_string(),
                "-1".into(),
                "-1".into(),
                req_procs,
                req_time,
                req_mem,
                "1".into(),
                user.to_string(),
                group.to_string(),
                "-1".into(),
                "-1".into(),
                "-1".into(),
                "-1".into(),
                "-1".into(),
            ]
        }
        TraceFormat::Gwf => vec![
            id.to_string(),
            gwf_num(rng, submit),
            "0".into(),
            gwf_num(rng, run),
            used.to_string(),
            "-1".into(),
            "-1".into(),
            req_procs,
            req_time,
            req_mem,
            "1".into(),
            user.to_string(),
            group.to_string(),
            "14".into(),
            "-1".into(),
        ],
        TraceFormat::Stf => unreachable!("stf is binary; this suite generates text bodies"),
    };
    let mut line = String::new();
    if rng.below(8) == 0 {
        line.push_str(sep(rng)); // leading whitespace
    }
    for (i, f) in fields.iter().enumerate() {
        if i > 0 {
            line.push_str(sep(rng));
        }
        line.push_str(f);
    }
    if rng.below(8) == 0 {
        line.push_str(sep(rng)); // trailing whitespace
    }
    line
}

/// A structurally broken line for the error-position contract.
fn gen_bad_line(rng: &mut Rng, format: TraceFormat) -> &'static str {
    match rng.below(3) {
        0 => "7 42 3", // too few fields
        // Junk token in field 1 — the per-field parse error path.
        1 => match format {
            TraceFormat::Swf => "12x7 0 -1 10 2 -1 -1 2 20 -1 1 0 0 -1 -1 -1 -1 -1",
            _ => "12x7 0 0 10 2 -1 -1 2 20 -1 1 0 0 14 -1",
        },
        // Overflowing i64 (SWF) / lone sign (GWF) — the cold-path
        // fallback must reproduce `str::parse`'s exact verdict.
        _ => match format {
            TraceFormat::Swf => {
                "1 999999999999999999999999 -1 10 2 -1 -1 2 20 -1 1 0 0 -1 -1 -1 -1 -1"
            }
            _ => "1 - 0 10 2 -1 -1 2 20 -1 1 0 0 14 -1",
        },
    }
}

/// A whole trace body: header comments, blanks, whitespace-only lines,
/// records; optionally one corrupted line and a truncated final line.
/// Returns the body and the chosen line ending.
fn gen_body(rng: &mut Rng, format: TraceFormat, with_bad: bool) -> String {
    let comment = match format {
        TraceFormat::Swf => ';',
        TraceFormat::Gwf => '#',
        TraceFormat::Stf => unreachable!("stf is binary; this suite generates text bodies"),
    };
    let eol = if rng.below(3) == 0 { "\r\n" } else { "\n" };
    let mut out = format!("{comment} generated header{eol}{comment} UnixStartTime: 0{eol}");
    let records = 1 + rng.below(30);
    let bad_at = if with_bad { rng.below(records) } else { u64::MAX };
    let mut submit = 0u64;
    for i in 0..records {
        submit += rng.below(500);
        match rng.below(12) {
            0 => out.push_str(eol),                                       // blank line
            1 => out.push_str(&format!("  \t{eol}")),                     // whitespace-only
            2 => out.push_str(&format!("{comment} interleaved {i}{eol}")), // comment
            _ => {}
        }
        if i == bad_at {
            out.push_str(gen_bad_line(rng, format));
        } else {
            out.push_str(&gen_record(rng, format, i + 1, submit));
        }
        out.push_str(eol);
    }
    if !with_bad && rng.below(4) == 0 {
        // Truncated final line: strip the trailing newline.
        out.truncate(out.len() - eol.len());
    }
    out
}

fn eager_parse(body: &str, format: TraceFormat) -> anyhow::Result<Vec<Job>> {
    match format {
        TraceFormat::Swf => parse_swf(body),
        TraceFormat::Gwf => parse_gwf(body),
        TraceFormat::Stf => unreachable!("stf is binary; this suite generates text bodies"),
    }
}

fn fast_parse(body: &str, format: TraceFormat) -> anyhow::Result<Vec<Job>> {
    FastTrace::from_bytes("prop", format, body.as_bytes().to_vec())?.parse()
}

#[test]
fn fast_parse_equals_scalar_parse() {
    for format in [TraceFormat::Swf, TraceFormat::Gwf] {
        check_n(&format!("fast==scalar/{format:?}"), 300, |rng| {
            let body = gen_body(rng, format, false);
            let fast = fast_parse(&body, format)
                .map_err(|e| format!("fast failed on a clean body: {e:#}\n{body}"))?;
            let scalar = eager_parse(&body, format)
                .map_err(|e| format!("scalar failed on a clean body: {e:#}\n{body}"))?;
            if fast.len() != scalar.len() {
                return Err(format!(
                    "record counts differ: fast {} vs scalar {}\n{body}",
                    fast.len(),
                    scalar.len()
                ));
            }
            for (a, b) in fast.iter().zip(&scalar) {
                if !jobs_equal(a, b) {
                    return Err(format!(
                        "record {} differs between fast and scalar\n{a:?}\n{b:?}\n{body}",
                        a.id
                    ));
                }
            }
            Ok(())
        });
    }
}

#[test]
fn fast_error_position_matches_scalar_stream_exactly() {
    for format in [TraceFormat::Swf, TraceFormat::Gwf] {
        check_n(&format!("fast-errs/{format:?}"), 200, |rng| {
            let body = gen_body(rng, format, true);
            let fast_err = match fast_parse(&body, format) {
                Err(e) => format!("{e:#}"),
                Ok(_) => return Err(format!("fast accepted a corrupt body\n{body}")),
            };
            let stream_err = match JobStream::new(
                Cursor::new(body.as_bytes().to_vec()),
                format,
            )
            .collect::<anyhow::Result<Vec<Job>>>()
            {
                Err(e) => format!("{e:#}"),
                Ok(_) => return Err(format!("scalar stream accepted a corrupt body\n{body}")),
            };
            // Same line number AND byte offset, string-for-string.
            if fast_err != stream_err {
                return Err(format!(
                    "error envelopes differ:\n fast:   {fast_err}\n stream: {stream_err}\n{body}"
                ));
            }
            // The eager parser's message (line number included) is
            // embedded verbatim in the fast error.
            let eager_err = match eager_parse(&body, format) {
                Err(e) => format!("{e:#}"),
                Ok(_) => return Err(format!("eager accepted a corrupt body\n{body}")),
            };
            if !fast_err.contains(&eager_err) {
                return Err(format!(
                    "eager message not embedded:\n fast:  {fast_err}\n eager: {eager_err}\n{body}"
                ));
            }
            Ok(())
        });
    }
}

#[test]
fn stf_roundtrip_is_identity() {
    check_n("stf-roundtrip", 200, |rng| {
        let n = rng.below(60) as usize;
        let mut submit = 0u64;
        let jobs: Vec<Job> = (0..n)
            .map(|i| {
                submit += rng.below(1_000);
                Job::new(
                    i as u64 + 1,
                    SimTime(submit),
                    1 + rng.below(128),
                    rng.below(1 << 20),
                    SimDuration(1 + rng.below(100_000)),
                    SimDuration(1 + rng.below(100_000)),
                    rng.below(1 << 16) as u32,
                    rng.below(1 << 16) as u32,
                )
            })
            .collect();
        let machine = if rng.below(2) == 0 { Some((128usize, 1u64)) } else { None };
        let bytes = stf::write_stf(&jobs, machine)
            .map_err(|e| format!("write_stf failed: {e:#}"))?;
        if bytes.len() != stf::HEADER_BYTES + n * stf::RECORD_BYTES {
            return Err(format!("unexpected image size {}", bytes.len()));
        }
        let trace = FastTrace::from_bytes("t.stf", TraceFormat::Stf, bytes)
            .map_err(|e| format!("validate failed: {e:#}"))?;
        let back = trace.parse().map_err(|e| format!("stf parse failed: {e:#}"))?;
        if back.len() != jobs.len() {
            return Err(format!("count changed: {} -> {}", jobs.len(), back.len()));
        }
        for (a, b) in jobs.iter().zip(&back) {
            if !jobs_equal(a, b) {
                return Err(format!("job {} changed across the roundtrip\n{a:?}\n{b:?}", a.id));
            }
        }
        Ok(())
    });
}

/// The streamed fast iterator and the borrowing one share a scanner:
/// identical yields, and the `yielded` counter ticks per record.
#[test]
fn fast_stream_is_incremental_and_matches_records() {
    let mut rng = Rng::new(0xFA57);
    let body = gen_body(&mut rng, TraceFormat::Swf, false);
    let trace =
        FastTrace::from_bytes("t.swf", TraceFormat::Swf, body.as_bytes().to_vec()).unwrap();
    let eager: Vec<Job> = trace.records().map(|r| r.unwrap()).collect();
    let mut s = trace.into_stream();
    let mut seen = 0u64;
    loop {
        let Some(r) = s.next() else { break };
        let job = r.unwrap();
        assert!(jobs_equal(&job, &eager[seen as usize]));
        seen += 1;
        assert_eq!(s.yielded(), seen, "yielded counter must tick per record");
    }
    assert_eq!(seen as usize, eager.len());
}

/// End-to-end converter check: SWF text -> stf file -> jobs is exactly
/// the scalar parser's job sequence (comments and cancelled records
/// dropped at conversion, machine recorded in the header).
#[test]
fn convert_swf_file_preserves_job_sequence() {
    let mut rng = Rng::new(0xC04E);
    let body = gen_body(&mut rng, TraceFormat::Swf, false);
    let scalar = parse_swf(&body).unwrap();
    let dir = std::env::temp_dir();
    let swf_path = dir.join("sst_sched_prop_convert.swf");
    let stf_path = dir.join("sst_sched_prop_convert.stf");
    std::fs::write(&swf_path, &body).unwrap();
    let stats = stf::convert_trace_file(swf_path.to_str().unwrap(), stf_path.to_str().unwrap())
        .unwrap();
    assert_eq!(stats.records as usize, scalar.len());
    assert_eq!(stats.machine, TraceFormat::Swf.default_machine());
    let trace = FastTrace::open(stf_path.to_str().unwrap()).unwrap();
    assert_eq!(trace.format(), TraceFormat::Stf);
    assert_eq!(trace.machine(), (128, 1));
    let back = trace.parse().unwrap();
    let _ = std::fs::remove_file(&swf_path);
    let _ = std::fs::remove_file(&stf_path);
    assert_eq!(back.len(), scalar.len());
    for (a, b) in back.iter().zip(&scalar) {
        assert!(jobs_equal(a, b), "job {} changed through conversion", b.id);
    }
}
