//! Property suite for the ladder event queue (`core::event::EventQueue`):
//! its pop sequence must equal a `BinaryHeap` oracle's on arbitrary
//! `(time, priority)` workloads — the determinism contract that keeps
//! engine fingerprints byte-identical to the heap-era seed engine.
//! Covers same-key FIFO, the `pop_before` / `pop_at_or_before` window
//! semantics the parallel rank loops rely on, and interleaved push/pop
//! (including pushes into the already-consumed near past, the engine's
//! same-tick self-send pattern).

use sst_sched::core::event::{EventQueue, Priority};
use sst_sched::core::rng::Rng;
use sst_sched::core::time::SimTime;
use sst_sched::util::prop::check_n;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// The seed engine's structure: a min-heap over the identical
/// `(time, priority, seq)` key, with payload riding along.
struct HeapOracle {
    heap: BinaryHeap<Reverse<(u64, u8, u64, u64)>>,
    seq: u64,
}

impl HeapOracle {
    fn new() -> HeapOracle {
        HeapOracle { heap: BinaryHeap::new(), seq: 0 }
    }

    fn push(&mut self, time: u64, priority: u8, payload: u64) {
        self.heap.push(Reverse((time, priority, self.seq, payload)));
        self.seq += 1;
    }

    /// Pop the minimum if `pred(time)` holds (mirrors the queue's
    /// bounded pops; `|_| true` is a plain pop).
    fn pop_if(&mut self, pred: impl Fn(u64) -> bool) -> Option<(u64, u8, u64)> {
        match self.heap.peek() {
            Some(&Reverse((t, _, _, _))) if pred(t) => {
                let Reverse((t, p, _, payload)) = self.heap.pop().unwrap();
                Some((t, p, payload))
            }
            _ => None,
        }
    }

    fn peek_time(&self) -> Option<u64> {
        self.heap.peek().map(|&Reverse((t, _, _, _))| t)
    }
}

/// Draw a timestamp mixing magnitudes so runs exercise the bottom rung,
/// nested rungs and the top tail (plus dense same-time clusters).
fn draw_time(rng: &mut Rng) -> u64 {
    match rng.below(8) {
        0 | 1 => rng.below(16),                      // dense near cluster
        2 | 3 => rng.below(2_000),                   // near
        4 => 50_000 + rng.below(50),                 // dense far cluster
        5 => rng.below(1_000_000),                   // mid
        6 => rng.below(1_000_000_000),               // far
        _ => 10_000_000_000 + rng.below(1_000_000),  // very far band
    }
}

fn draw_priority(rng: &mut Rng) -> u8 {
    rng.below(4) as u8
}

/// Bulk pushes then a full drain: the pop sequence equals the oracle's
/// exactly — times, priorities and payload identity.
#[test]
fn drain_matches_heap_oracle() {
    check_n("ladder vs heap: bulk drain", 200, |rng| {
        let n = rng.range(1, 800);
        let mut q = EventQueue::new();
        let mut oracle = HeapOracle::new();
        for i in 0..n {
            let (t, p) = (draw_time(rng), draw_priority(rng));
            q.push(SimTime(t), Priority(p), 0, i);
            oracle.push(t, p, i);
        }
        if q.len() != n as usize {
            return Err(format!("len {} after {n} pushes", q.len()));
        }
        for step in 0..n {
            let want = oracle.pop_if(|_| true).unwrap();
            let got = q.pop().ok_or_else(|| format!("queue dry at step {step}"))?;
            let got = (got.time.ticks(), got.priority.0, got.payload);
            if got != want {
                return Err(format!("pop {step}: ladder {got:?} != heap {want:?}"));
            }
        }
        if q.pop().is_some() {
            return Err("queue still had events after the oracle drained".into());
        }
        Ok(())
    });
}

/// Interleaved pushes and pops — including pushes at or before the
/// current minimum (the engine's same-tick self-sends land in the
/// already-sorted bottom rung) — stay in lock-step with the oracle.
#[test]
fn interleaved_ops_match_heap_oracle() {
    check_n("ladder vs heap: interleaved", 150, |rng| {
        let mut q = EventQueue::new();
        let mut oracle = HeapOracle::new();
        let mut payload = 0u64;
        let mut last_popped = 0u64;
        for step in 0..rng.range(50, 1_200) {
            if rng.chance(0.55) || q.is_empty() {
                // Mostly future pushes; some land exactly at (or just
                // after) the last popped time — the same-tick pattern.
                let t = if rng.chance(0.3) {
                    last_popped + rng.below(3)
                } else {
                    last_popped + draw_time(rng)
                };
                let p = draw_priority(rng);
                q.push(SimTime(t), Priority(p), 0, payload);
                oracle.push(t, p, payload);
                payload += 1;
            } else {
                let want = oracle.pop_if(|_| true);
                let got = q.pop().map(|e| (e.time.ticks(), e.priority.0, e.payload));
                if got != want {
                    return Err(format!("step {step}: ladder {got:?} != heap {want:?}"));
                }
                if let Some((t, _, _)) = got {
                    last_popped = t;
                }
            }
            if q.len() != oracle.heap.len() {
                return Err(format!(
                    "len diverged: ladder {} heap {}",
                    q.len(),
                    oracle.heap.len()
                ));
            }
        }
        Ok(())
    });
}

/// `pop_before` / `pop_at_or_before` window semantics: exactly the
/// oracle's bounded pops, with the boundary event excluded resp.
/// included, and `peek_time` agreeing after every window.
#[test]
fn window_pops_match_heap_oracle() {
    check_n("ladder vs heap: windows", 150, |rng| {
        let mut q = EventQueue::new();
        let mut oracle = HeapOracle::new();
        let n = rng.range(20, 600);
        for i in 0..n {
            let (t, p) = (draw_time(rng), draw_priority(rng));
            q.push(SimTime(t), Priority(p), 0, i);
            oracle.push(t, p, i);
        }
        let mut bound = 0u64;
        while !q.is_empty() {
            bound += rng.below(100_000_000);
            let inclusive = rng.chance(0.5);
            loop {
                let want = if inclusive {
                    oracle.pop_if(|t| t <= bound)
                } else {
                    oracle.pop_if(|t| t < bound)
                };
                let got = if inclusive {
                    q.pop_at_or_before(SimTime(bound))
                } else {
                    q.pop_before(SimTime(bound))
                };
                let got = got.map(|e| (e.time.ticks(), e.priority.0, e.payload));
                if got != want {
                    return Err(format!(
                        "window(bound={bound}, inclusive={inclusive}): \
                         ladder {got:?} != heap {want:?}"
                    ));
                }
                if got.is_none() {
                    break;
                }
            }
            if q.peek_time().map(|t| t.ticks()) != oracle.peek_time() {
                return Err(format!(
                    "peek diverged after window at {bound}: ladder {:?} heap {:?}",
                    q.peek_time(),
                    oracle.peek_time()
                ));
            }
        }
        if oracle.heap.pop().is_some() {
            return Err("oracle still had events after the ladder drained".into());
        }
        Ok(())
    });
}

/// Same-key FIFO at scale: a storm of events sharing one
/// `(time, priority)` — far larger than any internal batch threshold —
/// pops in exact push order, interleaved correctly with neighbors at
/// adjacent priorities and times.
#[test]
fn same_key_fifo_at_scale() {
    let mut q = EventQueue::new();
    let mut oracle = HeapOracle::new();
    let mut payload = 0u64;
    // Neighbor events bracketing the storm in time and priority.
    for (t, p) in [(999u64, 1u8), (1_000, 0), (1_000, 2), (1_001, 1), (5_000_000, 1)] {
        q.push(SimTime(t), Priority(p), 0, payload);
        oracle.push(t, p, payload);
        payload += 1;
    }
    for _ in 0..5_000 {
        q.push(SimTime(1_000), Priority(1), 0, payload);
        oracle.push(1_000, 1, payload);
        payload += 1;
    }
    let mut last_storm_payload = None;
    while let Some(want) = oracle.pop_if(|_| true) {
        let got = q.pop().map(|e| (e.time.ticks(), e.priority.0, e.payload)).unwrap();
        assert_eq!(got, want, "pop diverged from oracle");
        if got.0 == 1_000 && got.1 == 1 {
            // FIFO within the storm: payloads strictly ascend.
            if let Some(prev) = last_storm_payload {
                assert!(got.2 > prev, "same-key FIFO violated: {prev} then {}", got.2);
            }
            last_storm_payload = Some(got.2);
        }
    }
    assert!(q.is_empty());
}

/// One large deterministic end-to-end drain (hundreds of thousands of
/// events through nested rung refinement) as a smoke-scale pin on top
/// of the randomized cases.
#[test]
fn large_mixed_horizon_drain_is_totally_ordered() {
    let mut q = EventQueue::new();
    let mut rng = Rng::new(0xDE5_1ADDE);
    let n = 200_000u64;
    for i in 0..n {
        q.push(SimTime(draw_time(&mut rng)), Priority(draw_priority(&mut rng)), 0, i);
    }
    let mut popped = 0u64;
    let mut last: Option<(u64, u8, u64)> = None;
    while let Some(e) = q.pop() {
        let k = (e.time.ticks(), e.priority.0, e.seq);
        if let Some(prev) = last {
            assert!(prev < k, "total order violated: {prev:?} then {k:?}");
        }
        last = Some(k);
        popped += 1;
    }
    assert_eq!(popped, n);
}
