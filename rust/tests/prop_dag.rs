//! Property tests: DAG / workflow-management invariants on random DAGs
//! (random layered graphs, as in Gupta et al. 2017, which the paper cites
//! for DAG generation).

use sst_sched::core::rng::Rng;
use sst_sched::core::time::SimTime;
use sst_sched::parallel::run_workflow_parallel_modeled;
use sst_sched::util::prop::{check, check_n};
use sst_sched::workflow::task::Task;
use sst_sched::workflow::{Workflow, WorkflowExecutor, WorkflowManager};

/// Random layered DAG: tasks in layers, edges only point downward (so the
/// graph is acyclic by construction).
fn random_workflow(rng: &mut Rng) -> Workflow {
    let layers = rng.range(1, 6) as usize;
    let mut tasks: Vec<Task> = Vec::new();
    let mut prev_layer: Vec<u64> = Vec::new();
    let mut next_id = 1u64;
    for _ in 0..layers {
        let width = rng.range(1, 8) as usize;
        let mut this_layer = Vec::new();
        for _ in 0..width {
            let mut deps = Vec::new();
            for &p in &prev_layer {
                if rng.chance(0.4) {
                    deps.push(p);
                }
            }
            let t = Task::new(next_id, rng.range(1, 500), rng.range(1, 3), 0).with_deps(deps);
            this_layer.push(next_id);
            tasks.push(t);
            next_id += 1;
        }
        prev_layer = this_layer;
    }
    Workflow::new(1, "random", tasks).expect("layered construction is acyclic")
}

#[test]
fn topo_sort_respects_every_edge() {
    check("topo respects edges", |rng| {
        let w = random_workflow(rng);
        let order = w.dag.topo_sort().ok_or("cycle in layered DAG?!")?;
        let pos: std::collections::HashMap<u64, usize> =
            order.iter().enumerate().map(|(i, &id)| (id, i)).collect();
        for id in w.dag.nodes() {
            for &child in w.dag.children(id) {
                if pos[&id] >= pos[&child] {
                    return Err(format!("edge {id}->{child} violated"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn manager_never_readies_task_before_dependencies() {
    check("manager ready-set", |rng| {
        let w = random_workflow(rng);
        let mut mgr = WorkflowManager::new(w, SimTime::ZERO);
        let mut t = 0u64;
        // Random-order execution of ready tasks until done.
        while !mgr.all_done() {
            let ready = mgr.ready_tasks();
            if ready.is_empty() && mgr.num_running() == 0 {
                return Err("deadlock: nothing ready, nothing running".into());
            }
            if !ready.is_empty() {
                let pick = ready[rng.below(ready.len() as u64) as usize];
                mgr.mark_started(pick, SimTime(t));
                t += 1;
                mgr.mark_completed(pick, SimTime(t));
            }
            if !mgr.check_invariants() {
                return Err("manager invariants violated".into());
            }
        }
        Ok(())
    });
}

#[test]
fn executor_respects_dependencies_and_critical_path() {
    check("executor correctness", |rng| {
        let w = random_workflow(rng);
        let crit = w.critical_path_time();
        let total = w.total_work();
        let cpu = rng.range(3, 16); // >= max task cpu (3)
        let dag = w.dag.clone();
        let rep = WorkflowExecutor::new(cpu, u64::MAX).run(w);
        let by_id: std::collections::HashMap<_, _> =
            rep.tasks.iter().map(|t| (t.id, *t)).collect();
        for id in dag.nodes() {
            for &child in dag.children(id) {
                if by_id[&child].start < by_id[&id].end {
                    return Err(format!("task {child} started before parent {id} ended"));
                }
            }
        }
        let ms = rep.makespan.as_f64();
        if ms + 1e-9 < crit {
            return Err(format!("makespan {ms} below critical path {crit}"));
        }
        if ms > total + 1e-9 {
            return Err(format!("makespan {ms} above serial bound {total}"));
        }
        Ok(())
    });
}

#[test]
fn distributed_execution_matches_task_count_any_partition() {
    check_n("distributed completeness", 60, |rng| {
        let w = random_workflow(rng);
        let n = w.len() as u64;
        let ranks = rng.range(1, 6) as usize;
        // Pool per rank must cover the largest task (cpu <= 3).
        let rep = run_workflow_parallel_modeled(&w, ranks, 3 * ranks as u64 + 8, rng.range(1, 20));
        if rep.total_completed() != n {
            return Err(format!(
                "{} of {n} tasks completed across {ranks} ranks",
                rep.total_completed()
            ));
        }
        // Makespan never below the critical path (latency only stretches).
        if (rep.end_time() as f64) + 1e-9 < w.critical_path_time() {
            return Err("distributed makespan below critical path".into());
        }
        Ok(())
    });
}

#[test]
fn spec_roundtrip_preserves_semantics() {
    check_n("spec roundtrip", 60, |rng| {
        let w = random_workflow(rng);
        let spec = sst_sched::workflow::WorkflowSpec {
            workflow: w.clone(),
            cpu_available: 8,
            memory_available_mb: u64::MAX,
            scheduling_policy: "Static".into(),
            preemption: false,
        };
        let text = spec.to_json().to_pretty();
        let back = sst_sched::workflow::WorkflowSpec::parse(&text)
            .map_err(|e| format!("reparse failed: {e:#}"))?;
        if back.workflow.len() != w.len() {
            return Err("task count changed through roundtrip".into());
        }
        let a = WorkflowExecutor::new(8, u64::MAX).run(w);
        let b = WorkflowExecutor::new(8, u64::MAX).run(back.workflow);
        if a.makespan != b.makespan {
            return Err(format!(
                "roundtrip changed makespan: {} vs {}",
                a.makespan.ticks(),
                b.makespan.ticks()
            ));
        }
        Ok(())
    });
}
