//! Property tests for the fault/preemption/reservation subsystem: random
//! workloads under random failure models, preemption modes, priorities
//! and reservations, checking the invariants the subsystem promises:
//!
//! * no job ever occupies a `Down` node (audited after every capacity
//!   transition; `Draining` keeps its occupants by design, and the
//!   allocation planner refuses `Draining`/`Reserved`/`Down` nodes);
//! * core accounting is conserved across fail -> preempt -> requeue ->
//!   repair cycles: at the end of every run the cluster is pristine;
//! * runtime accounting is exact: a completed job's total charged
//!   machine time equals its runtime plus checkpoint/restart overhead
//!   plus lost (redone) work, and a checkpoint-evicted, never-failed
//!   job's total is exactly `runtime + preemptions * (ckpt + restart)`;
//! * no job is ever lost: everything admitted eventually completes.

use sst_sched::core::rng::Rng;
use sst_sched::core::time::SimDuration;
use sst_sched::job::Job;
use sst_sched::resources::NodeState;
use sst_sched::sched::{Policy, PreemptionConfig, PreemptionMode};
use sst_sched::sim::{FaultConfig, ReservationSpec, SchedulerComponent, Simulation};
use sst_sched::trace::Workload;
use sst_sched::util::prop::check_n;

fn random_workload(rng: &mut Rng) -> Workload {
    let nodes = rng.range(2, 12) as usize;
    let cores = rng.range(1, 8);
    let total = nodes as u64 * cores;
    let jobs: Vec<Job> = (0..rng.range(20, 120))
        .map(|i| {
            let mut j = Job::with_estimate(
                i,
                rng.range(0, 20_000),
                rng.range(1, total),
                rng.range(10, 2_000),
                rng.range(10, 4_000),
            );
            j.priority = rng.range(0, 3) as u8;
            j
        })
        .collect();
    Workload::new("fault-prop", jobs, nodes, cores)
}

fn random_mode(rng: &mut Rng) -> PreemptionConfig {
    let mode = match rng.below(3) {
        0 => PreemptionMode::None,
        1 => PreemptionMode::Kill,
        _ => PreemptionMode::Checkpoint,
    };
    PreemptionConfig {
        mode,
        checkpoint_overhead: SimDuration(rng.range(0, 120)),
        restart_overhead: SimDuration(rng.range(0, 120)),
        starvation_threshold: SimDuration(if rng.chance(0.5) { 0 } else { rng.range(500, 5_000) }),
    }
}

fn random_policy(rng: &mut Rng) -> Policy {
    Policy::ALL[rng.below(Policy::ALL.len() as u64) as usize]
}

/// Run one random fault-injected scenario and check every invariant on
/// the final component state. Returns an error string on violation.
fn run_and_audit(rng: &mut Rng, with_reservations: bool) -> Result<(), String> {
    let w = random_workload(rng);
    let n_jobs = w.jobs.len();
    let policy = random_policy(rng);
    let preemption = random_mode(rng);
    let faults = FaultConfig {
        mtbf: rng.range(500, 20_000) as f64,
        mttr: rng.range(100, 5_000) as f64,
        seed: rng.next_u64(),
        ..FaultConfig::default()
    };
    let reservations = if with_reservations {
        (0..rng.range(1, 3))
            .map(|_| ReservationSpec {
                start: rng.range(100, 25_000),
                duration: rng.range(500, 8_000),
                nodes: rng.range(1, w.nodes as u64) as usize,
            })
            .collect()
    } else {
        Vec::new()
    };
    let total_cores = w.total_cores();
    let num_nodes = w.nodes;
    let mut inst = Simulation::new(w, policy)
        .with_seed(rng.next_u64())
        .with_faults(faults)
        .with_preemption(preemption)
        .with_reservations(reservations)
        .build();
    inst.engine.run(None);
    let sched_id = inst.engine.id_of("scheduler").ok_or("no scheduler component")?;
    let s = inst.engine.get::<SchedulerComponent>(sched_id).ok_or("bad downcast")?;

    // Invariant: the placement audit never saw a job on a Down node.
    if s.fault_counters.invariant_violations != 0 {
        return Err(format!(
            "{} placements observed on Down nodes",
            s.fault_counters.invariant_violations
        ));
    }
    // Invariant: nothing lost — every admitted job completed.
    if s.completed.len() != n_jobs {
        return Err(format!(
            "completed {} of {n_jobs} jobs (queue={}, running={})",
            s.completed.len(),
            s.queue_len(),
            s.running_len()
        ));
    }
    // Invariant: conservation — the cluster ends pristine: every core
    // free again, every node repaired (repair chain always terminates)
    // and returned to service (reservations all expired).
    if !s.cluster.check_invariants() {
        return Err("cluster cached aggregates inconsistent at end".into());
    }
    if s.cluster.free_cores() != total_cores {
        return Err(format!(
            "core leak: {} of {total_cores} free at end",
            s.cluster.free_cores()
        ));
    }
    for state in [NodeState::Down, NodeState::Draining, NodeState::Reserved] {
        let stuck: Vec<usize> = s.cluster.nodes_in_state(state).collect();
        if !stuck.is_empty() {
            return Err(format!("nodes stuck in {state:?} at end: {stuck:?}"));
        }
    }
    if s.cluster.nodes().len() != num_nodes {
        return Err("node count changed".into());
    }
    // Invariant: exact runtime accounting on every completed job.
    for j in &s.completed {
        if j.executed.ticks() != j.runtime.ticks() + j.overhead.ticks() + j.lost.ticks() {
            return Err(format!(
                "job {}: executed {} != runtime {} + overhead {} + lost {}",
                j.id,
                j.executed.ticks(),
                j.runtime.ticks(),
                j.overhead.ticks(),
                j.lost.ticks()
            ));
        }
        if j.start.is_none() || j.end.is_none() {
            return Err(format!("job {} completed without timestamps", j.id));
        }
        // Never-interrupted jobs are charged exactly their runtime.
        if j.preempt_count == 0 && j.fail_count == 0 && j.executed != j.runtime {
            return Err(format!("untouched job {} charged {:?}", j.id, j.executed));
        }
    }
    Ok(())
}

#[test]
fn fault_runs_preserve_every_invariant() {
    check_n("fault invariants", 60, |rng| run_and_audit(rng, false));
}

#[test]
fn reservation_runs_preserve_every_invariant() {
    check_n("reservation invariants", 40, |rng| run_and_audit(rng, true));
}

#[test]
fn checkpoint_eviction_charges_exactly_runtime_plus_overheads() {
    // Deterministic scenario: one low-priority hog, one high-priority
    // starver that forces exactly one checkpointed eviction.
    // Machine: 1 node x 4 cores.
    let ckpt = 25u64;
    let restart = 15u64;
    let hog = {
        let mut j = Job::with_estimate(1, 0, 4, 10_000, 10_000);
        j.priority = 0;
        j
    };
    let vip = {
        let mut j = Job::with_estimate(2, 10, 4, 500, 500);
        j.priority = 5;
        j
    };
    let w = Workload::new("evict-once", vec![hog, vip], 1, 4);
    let cfg = PreemptionConfig {
        mode: PreemptionMode::Checkpoint,
        checkpoint_overhead: SimDuration(ckpt),
        restart_overhead: SimDuration(restart),
        starvation_threshold: SimDuration(100),
    };
    let r = Simulation::new(w, Policy::Fcfs).with_preemption(cfg).run(None);
    assert_eq!(r.completed.len(), 2);
    assert_eq!(r.faults.preemptions, 1, "expected exactly one eviction");
    let hog = r.completed.iter().find(|j| j.id == 1).unwrap();
    assert_eq!(hog.preempt_count, 1);
    assert_eq!(hog.fail_count, 0);
    assert_eq!(hog.lost, SimDuration::ZERO, "checkpoint keeps progress");
    // The tentpole invariant: total charged runtime is exactly
    // original runtime + preemptions * (checkpoint + restart).
    assert_eq!(
        hog.executed.ticks(),
        hog.runtime.ticks() + u64::from(hog.preempt_count) * (ckpt + restart)
    );
    // The VIP ran clean.
    let vip = r.completed.iter().find(|j| j.id == 2).unwrap();
    assert_eq!(vip.executed, vip.runtime);
    assert_eq!(r.overhead_work, (ckpt + restart) as f64 * 4.0);
}

#[test]
fn kill_mode_eviction_redoes_work() {
    let hog = {
        let mut j = Job::with_estimate(1, 0, 4, 1_000, 1_000);
        j.priority = 0;
        j
    };
    let vip = {
        let mut j = Job::with_estimate(2, 10, 4, 200, 200);
        j.priority = 5;
        j
    };
    let w = Workload::new("kill-once", vec![hog, vip], 1, 4);
    let cfg = PreemptionConfig {
        mode: PreemptionMode::Kill,
        checkpoint_overhead: SimDuration(0),
        restart_overhead: SimDuration(0),
        starvation_threshold: SimDuration(100),
    };
    let r = Simulation::new(w, Policy::Fcfs).with_preemption(cfg).run(None);
    assert_eq!(r.completed.len(), 2);
    let hog = r.completed.iter().find(|j| j.id == 1).unwrap();
    assert_eq!(hog.preempt_count, 1);
    assert!(hog.lost > SimDuration::ZERO, "kill must discard progress");
    assert_eq!(
        hog.executed.ticks(),
        hog.runtime.ticks() + hog.lost.ticks(),
        "executed = runtime + redone work"
    );
    assert!(r.lost_work > 0.0);
    assert_eq!(r.overhead_work, 0.0);
}

#[test]
fn failed_node_kills_only_its_occupants() {
    // 2 nodes x 4 cores; two 4-core jobs, one per node. Fail node 0 at
    // t=50 (explicit trace via a 1-event MTBF window is fiddly, so use
    // the deterministic reservation-free injection seed and assert via
    // counters instead): here we instead drive the component through a
    // tiny fault model with mtbf small and until tight, then check that
    // exactly the jobs with fail_count > 0 redid work.
    let jobs = vec![Job::simple(1, 0, 4, 5_000), Job::simple(2, 0, 4, 5_000)];
    let w = Workload::new("fail-kill", jobs, 2, 4);
    let faults = FaultConfig { mtbf: 1_000.0, mttr: 500.0, seed: 42, until: Some(4_000), ..FaultConfig::default() };
    let r = Simulation::new(w, Policy::Fcfs).with_faults(faults).run(None);
    assert_eq!(r.completed.len(), 2, "both jobs must finish after repairs");
    assert!(r.faults.failures > 0, "seeded model must inject at least one failure");
    assert_eq!(r.faults.failures, r.faults.repairs, "every failure repairs");
    for j in &r.completed {
        if j.fail_count == 0 {
            assert_eq!(j.lost, SimDuration::ZERO);
            assert_eq!(j.executed, j.runtime);
        } else {
            assert_eq!(j.executed.ticks(), j.runtime.ticks() + j.lost.ticks());
        }
    }
}

#[test]
fn reservation_holds_nodes_and_releases_them() {
    // One long job; reserve both nodes mid-run under checkpoint
    // preemption: the job must be evicted, wait out the reservation,
    // then finish — and charge exactly one overhead. The job *under-
    // estimates* its runtime (400 of 2000): with an honest estimate the
    // reservation-aware admission would hold it back until the window
    // passes (see fcfs_head_waits_for_future_reservation), so the
    // mid-run eviction path is exactly the estimate-overrun path.
    let job = Job::with_estimate(1, 0, 8, 2_000, 400);
    let w = Workload::new("resv", vec![job], 2, 4);
    let cfg = PreemptionConfig {
        mode: PreemptionMode::Checkpoint,
        checkpoint_overhead: SimDuration(10),
        restart_overhead: SimDuration(10),
        starvation_threshold: SimDuration(0),
    };
    let resv = vec![ReservationSpec { start: 500, duration: 1_000, nodes: 2 }];
    let r = Simulation::new(w, Policy::FcfsBackfill)
        .with_preemption(cfg)
        .with_reservations(resv)
        .run(None);
    assert_eq!(r.completed.len(), 1);
    assert_eq!(r.faults.reservations_started, 1);
    assert_eq!(r.faults.preemptions, 1);
    let j = &r.completed[0];
    assert_eq!(j.preempt_count, 1);
    // Evicted at 500 (ran 500 of 2000), resumes at 1500 with
    // 1500 + 20 overhead to go => ends at 3020.
    assert_eq!(j.end.unwrap().ticks(), 3_020);
    assert_eq!(j.executed.ticks(), 2_000 + 20);
}

#[test]
fn degraded_reservation_drains_without_preemption() {
    // Same scenario, preemption off: the job keeps running (drains) and
    // the reservation is recorded as degraded; the job is never killed.
    // Again an under-estimate — honestly-estimated heads now wait out
    // declared reservation windows instead of running into them.
    let job = Job::with_estimate(1, 0, 8, 2_000, 400);
    let w = Workload::new("resv-drain", vec![job], 2, 4);
    let resv = vec![ReservationSpec { start: 500, duration: 1_000, nodes: 2 }];
    let r = Simulation::new(w, Policy::Fcfs).with_reservations(resv).run(None);
    assert_eq!(r.completed.len(), 1);
    assert_eq!(r.faults.preemptions, 0);
    assert_eq!(r.faults.reservations_degraded, 2, "both nodes drained");
    let j = &r.completed[0];
    assert_eq!(j.end.unwrap().ticks(), 2_000, "drain does not disturb the job");
    assert_eq!(j.executed, j.runtime);
    assert_eq!(r.faults.reservations_short_nodes, 0, "full claim has no shortfall");
}

#[test]
fn oversized_reservation_reports_its_shortfall() {
    // Ask for 5 nodes on a 2-node machine: the claim truncates and the
    // 3-node shortfall must be visible in the counters.
    let w = Workload::new("resv-short", vec![Job::simple(1, 0, 1, 100)], 2, 4);
    let resv = vec![ReservationSpec { start: 10, duration: 100, nodes: 5 }];
    let r = Simulation::new(w, Policy::Fcfs).with_reservations(resv).run(None);
    assert_eq!(r.faults.reservations_started, 1);
    assert_eq!(r.faults.reservations_short_nodes, 3);
    assert_eq!(r.completed.len(), 1);
}
