//! XLA/native parity: the AOT-compiled JAX+Pallas scorer must produce
//! the same *scheduling decisions* as the pure-Rust scorer — backend
//! choice is a performance knob, never a semantics knob.
//!
//! Requires `make artifacts`; tests self-skip when the artifact is
//! missing so `cargo test` stays green on fresh checkouts. The whole
//! suite needs the `xla` cargo feature (PJRT bindings are not in the
//! offline crate set).

#![cfg(feature = "xla")]

use sst_sched::core::rng::Rng;
use sst_sched::runtime::{backfill_with_accel, Accel, XlaScorer, DEFAULT_ARTIFACT};
use sst_sched::sched::scorer::{NativeScorer, QueueScorer, ScoreParams};
use sst_sched::sched::Policy;
use sst_sched::sim::Simulation;
use sst_sched::trace::{Das2Model, SdscSp2Model};
use sst_sched::util::prop::check_n;

fn artifact() -> bool {
    let here = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(DEFAULT_ARTIFACT);
    if here.exists() {
        std::env::set_current_dir(env!("CARGO_MANIFEST_DIR")).unwrap();
        true
    } else {
        eprintln!("skipping XLA parity tests: run `make artifacts`");
        false
    }
}

#[test]
fn scorer_outputs_match_on_random_inputs() {
    if !artifact() {
        return;
    }
    let mut xla = XlaScorer::load_default().unwrap();
    let mut native = NativeScorer::new();
    check_n("scorer parity", 40, |rng: &mut Rng| {
        let q = rng.range(1, 300) as usize;
        let n = rng.range(1, 400) as usize;
        let req: Vec<f32> = (0..q).map(|_| rng.range(0, 64) as f32).collect();
        let est: Vec<f32> = (0..q).map(|_| rng.range(1, 86_400) as f32).collect();
        let wait: Vec<f32> = (0..q).map(|_| rng.range(0, 50_000) as f32).collect();
        let free: Vec<f32> = (0..n).map(|_| rng.range(0, 16) as f32).collect();
        let params = ScoreParams {
            shadow_time: rng.range(0, 86_400) as f32,
            extra_cores: rng.range(0, 128) as f32,
            aging_weight: 1.0,
            waste_weight: 0.5,
        };
        let a = xla.score(&req, &est, &wait, &free, params);
        let b = native.score(&req, &est, &wait, &free, params);
        if a.backfill_ok != b.backfill_ok {
            return Err("backfill_ok mismatch".into());
        }
        for i in 0..q {
            let (x, y) = (a.waste[i], b.waste[i]);
            if (x - y).abs() > 1e-3 * y.abs().max(1.0) {
                return Err(format!("waste[{i}] {x} vs {y}"));
            }
            let (x, y) = (a.priority[i], b.priority[i]);
            if (x - y).abs() > 1e-2 * y.abs().max(1.0) {
                return Err(format!("priority[{i}] {x} vs {y}"));
            }
        }
        Ok(())
    });
}

fn decisions(accel: Accel, w: &sst_sched::trace::Workload) -> Vec<(u64, u64)> {
    let sched = backfill_with_accel(accel).unwrap();
    let r = Simulation::new(w.clone(), Policy::FcfsBackfill)
        .with_scheduler(Box::new(sched))
        .run(None);
    let mut v: Vec<(u64, u64)> =
        r.completed.iter().map(|j| (j.id, j.start.unwrap().ticks())).collect();
    v.sort_unstable();
    v
}

#[test]
fn das2_scheduling_decisions_identical() {
    if !artifact() {
        return;
    }
    let w = Das2Model::default().generate(3_000, 17).scale_arrivals(0.4).drop_infeasible();
    assert_eq!(decisions(Accel::Xla, &w), decisions(Accel::Native, &w));
}

#[test]
fn sp2_scheduling_decisions_identical() {
    if !artifact() {
        return;
    }
    // SP2: 128 nodes of 1 core — heavy backfilling traffic.
    let w = SdscSp2Model::default().generate(2_000, 23).drop_infeasible();
    assert_eq!(decisions(Accel::Xla, &w), decisions(Accel::Native, &w));
}

#[test]
fn long_queue_chunked_scoring_still_identical() {
    if !artifact() {
        return;
    }
    // Compress arrivals hard so queues exceed the artifact's Q_PAD=256
    // and the XLA scorer must chunk.
    let w = Das2Model::default().generate(2_000, 31).scale_arrivals(0.02).drop_infeasible();
    assert_eq!(decisions(Accel::Xla, &w), decisions(Accel::Native, &w));
}
