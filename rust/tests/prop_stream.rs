//! Property suite for streaming trace ingestion: `JobStream` over any
//! generated SWF/GWF body must reproduce the eager parser exactly —
//! same records in the same order (including `-1` sentinel handling,
//! comment/header lines, blanks and skipped cancelled records), and an
//! error on exactly the bodies the eager parser rejects (short lines).

use sst_sched::core::rng::Rng;
use sst_sched::job::Job;
use sst_sched::trace::{parse_gwf, parse_swf, JobStream, TraceFormat};
use sst_sched::util::prop::check_n;
use std::io::Cursor;

fn sentinel_or(rng: &mut Rng, val: u64) -> String {
    if rng.below(4) == 0 {
        "-1".to_string()
    } else {
        val.to_string()
    }
}

/// One record line with randomized `-1` sentinels and occasional
/// cancelled entries (non-positive runtime / processor count).
fn gen_record(rng: &mut Rng, format: TraceFormat, id: u64, submit: u64) -> String {
    let run = if rng.below(8) == 0 {
        "-1".to_string()
    } else {
        (1 + rng.below(5_000)).to_string()
    };
    let used = if rng.below(8) == 0 {
        "0".to_string()
    } else {
        (1 + rng.below(64)).to_string()
    };
    let req_procs = sentinel_or(rng, 1 + rng.below(64));
    let req_time = sentinel_or(rng, 1 + rng.below(9_000));
    let req_mem = sentinel_or(rng, 128 + rng.below(4_096));
    let user = rng.below(50);
    let group = rng.below(8);
    match format {
        TraceFormat::Swf => format!(
            "{id} {submit} -1 {run} {used} -1 -1 {req_procs} {req_time} {req_mem} 1 \
             {user} {group} -1 -1 -1 -1 -1"
        ),
        TraceFormat::Gwf => format!(
            "{id} {submit} 0 {run}.0 {used} -1 -1 {req_procs} {req_time} {req_mem} 1 \
             {user} {group} 14 -1"
        ),
        TraceFormat::Stf => unreachable!("stf is binary; this suite generates text bodies"),
    }
}

/// A whole trace body: header comments, blanks, records, and (when
/// `with_bad` draws true) one short line somewhere in the middle.
fn gen_body(rng: &mut Rng, format: TraceFormat, with_bad: bool) -> String {
    let comment = match format {
        TraceFormat::Swf => ';',
        TraceFormat::Gwf => '#',
        TraceFormat::Stf => unreachable!("stf is binary; this suite generates text bodies"),
    };
    let mut out = format!("{comment} generated header\n{comment} UnixStartTime: 0\n");
    let records = 1 + rng.below(40);
    let bad_at = if with_bad { rng.below(records) } else { u64::MAX };
    let mut submit = 0u64;
    for i in 0..records {
        submit += rng.below(500);
        if rng.below(10) == 0 {
            out.push('\n'); // blank line
        }
        if rng.below(10) == 0 {
            out.push_str(&format!("{comment} interleaved comment {i}\n"));
        }
        if i == bad_at {
            out.push_str("7 42 3\n"); // short line: structurally broken
        } else {
            out.push_str(&gen_record(rng, format, i + 1, submit));
            out.push('\n');
        }
    }
    out
}

fn stream_collect(body: &str, format: TraceFormat) -> anyhow::Result<Vec<Job>> {
    JobStream::new(Cursor::new(body.as_bytes().to_vec()), format).collect()
}

fn eager_parse(body: &str, format: TraceFormat) -> anyhow::Result<Vec<Job>> {
    match format {
        TraceFormat::Swf => parse_swf(body),
        TraceFormat::Gwf => parse_gwf(body),
        TraceFormat::Stf => unreachable!("stf is binary; this suite generates text bodies"),
    }
}

fn jobs_equal(a: &Job, b: &Job) -> bool {
    a.id == b.id
        && a.submit == b.submit
        && a.cores == b.cores
        && a.memory_mb == b.memory_mb
        && a.est_runtime == b.est_runtime
        && a.runtime == b.runtime
        && a.user == b.user
        && a.group == b.group
}

#[test]
fn stream_parse_equals_eager_parse() {
    for format in [TraceFormat::Swf, TraceFormat::Gwf] {
        check_n(&format!("stream==eager/{format:?}"), 200, |rng| {
            let body = gen_body(rng, format, false);
            let streamed = stream_collect(&body, format)
                .map_err(|e| format!("stream failed on a clean body: {e:#}"))?;
            let eager = eager_parse(&body, format)
                .map_err(|e| format!("eager failed on a clean body: {e:#}"))?;
            if streamed.len() != eager.len() {
                return Err(format!(
                    "record counts differ: streamed {} vs eager {}\n{body}",
                    streamed.len(),
                    eager.len()
                ));
            }
            for (a, b) in streamed.iter().zip(&eager) {
                if !jobs_equal(a, b) {
                    return Err(format!("record {} differs between paths\n{body}", a.id));
                }
            }
            Ok(())
        });
    }
}

#[test]
fn stream_errors_exactly_where_eager_errors() {
    for format in [TraceFormat::Swf, TraceFormat::Gwf] {
        check_n(&format!("stream-errs/{format:?}"), 100, |rng| {
            let body = gen_body(rng, format, true);
            let streamed = stream_collect(&body, format);
            let eager = eager_parse(&body, format);
            match (streamed.is_err(), eager.is_err()) {
                (true, true) => Ok(()),
                (s, e) => Err(format!(
                    "error disagreement: streamed err={s}, eager err={e}\n{body}"
                )),
            }
        });
    }
}

/// The stream is single-pass and bounded: records arrive one at a time
/// (the `yielded` counter ticks with each) — no internal batching.
#[test]
fn stream_is_incremental() {
    let mut rng = Rng::new(0xBEEF);
    let body = gen_body(&mut rng, TraceFormat::Swf, false);
    let expected = parse_swf(&body).unwrap().len() as u64;
    let mut s = JobStream::new(Cursor::new(body.into_bytes()), TraceFormat::Swf);
    let mut seen = 0u64;
    loop {
        let Some(r) = s.next() else { break };
        r.unwrap();
        seen += 1;
        assert_eq!(s.yielded(), seen, "yielded counter must tick per record");
    }
    assert_eq!(seen, expected);
}
