//! Resumable-simulation contract: a run that is stepped, snapshotted,
//! and resumed must produce a result fingerprint byte-identical to the
//! same run left uninterrupted. This is the property `sst-sched serve`
//! leans on for `predict_wait` — the speculative clone must be a
//! perfect fork of the live timeline.

use sst_sched::core::rng::Rng;
use sst_sched::core::time::SimTime;
use sst_sched::job::Job;
use sst_sched::sched::Policy;
use sst_sched::sim::{FaultConfig, SimInstance, Simulation};
use sst_sched::trace::Workload;
use sst_sched::util::prop::check_n;

fn gen_workload(rng: &mut Rng) -> Workload {
    let n = 5 + rng.below(40) as usize;
    let mut jobs = Vec::with_capacity(n);
    let mut t = 0u64;
    for i in 0..n {
        t += rng.below(300);
        let cores = 1 + rng.below(8);
        let runtime = 1 + rng.below(2_000);
        let est = runtime + rng.below(500);
        let mut job = Job::with_estimate(i as u64 + 1, t, cores, runtime, est);
        job.user = rng.below(5) as u32;
        jobs.push(job);
    }
    Workload::new("snap-prop", jobs, 4, 8)
}

fn build(workload: &Workload, policy: Policy, faults: Option<FaultConfig>, seed: u64) -> Simulation {
    let mut sim = Simulation::new(workload.clone(), policy).with_seed(seed);
    if let Some(f) = faults {
        sim = sim.with_faults(f);
    }
    sim
}

#[test]
fn snapshot_resume_is_byte_identical() {
    let policies = [
        Policy::Fcfs,
        Policy::Sjf,
        Policy::FcfsBackfill,
        Policy::ConservativeBackfill,
    ];
    check_n("snapshot-resume", 48, |rng| {
        let workload = gen_workload(rng);
        let policy = policies[rng.below(4) as usize];
        let faults = if rng.below(3) == 0 {
            Some(FaultConfig {
                mtbf: 20_000.0,
                mttr: 900.0,
                seed: 7,
                ..FaultConfig::default()
            })
        } else {
            None
        };
        let seed = rng.next_u64();
        let reference = build(&workload, policy, faults, seed).run(None).fingerprint();

        let cut = SimTime(rng.below(5_000));
        let mut inst = build(&workload, policy, faults, seed).build();
        inst.step_until(cut);
        let snap = inst.snapshot()?;
        let resumed = SimInstance::resume(snap).run_to_completion(None).fingerprint();
        if resumed != reference {
            return Err(format!(
                "snapshot at t={} diverged from the uninterrupted run:\n--- resumed\n{resumed}\n--- reference\n{reference}",
                cut.ticks()
            ));
        }
        // Snapshotting is read-only: the original instance, continued
        // past the cut, must land on the same fingerprint too.
        let original = inst.run_to_completion(None).fingerprint();
        if original != reference {
            return Err(format!(
                "taking a snapshot at t={} perturbed the live run",
                cut.ticks()
            ));
        }
        Ok(())
    });
}

#[test]
fn snapshot_of_snapshot_still_matches() {
    let jobs: Vec<Job> = (0..20)
        .map(|i| Job::simple(i + 1, i * 50, 1 + (i % 6), 300 + 17 * i))
        .collect();
    let workload = Workload::new("snap-chain", jobs, 3, 6);
    let reference = Simulation::new(workload.clone(), Policy::FcfsBackfill)
        .run(None)
        .fingerprint();

    let mut inst = Simulation::new(workload, Policy::FcfsBackfill).build();
    inst.step_until(SimTime(200));
    let mut hop = SimInstance::resume(inst.snapshot().expect("first snapshot"));
    hop.step_until(SimTime(600));
    let resumed = SimInstance::resume(hop.snapshot().expect("second snapshot"));
    assert_eq!(resumed.run_to_completion(None).fingerprint(), reference);
}

#[test]
fn streamed_sources_refuse_to_snapshot() {
    // A streamed job source reads from a live BufRead and cannot be
    // cloned; the error must name the offending component instead of
    // silently forking half a simulation.
    use sst_sched::trace::{JobStream, TraceFormat};
    let swf = "1 0 -1 10 1 -1 -1 1 10 -1 1 1 1 1 -1 -1 -1 -1\n";
    let stream = JobStream::new(std::io::Cursor::new(swf.as_bytes().to_vec()), TraceFormat::Swf);
    let inst = Simulation::new(Workload::machine("streamed", 2, 4), Policy::Fcfs)
        .with_job_stream(Box::new(stream.map(|j| j.unwrap())))
        .build();
    let err = inst.snapshot().expect_err("streamed sims must not snapshot");
    assert!(err.contains("source"), "error should name the component: {err}");
}
