//! Property tests: scheduling-discipline invariants over random
//! workloads, checked end-to-end through the event-driven simulator.

use sst_sched::core::rng::Rng;
use sst_sched::core::time::SimTime;
use sst_sched::job::Job;
use sst_sched::sched::Policy;
use sst_sched::sim::{run_policy, SimReport};
use sst_sched::trace::Workload;
use sst_sched::util::prop::check_n;

fn random_workload(rng: &mut Rng) -> Workload {
    let nodes = rng.range(1, 16) as usize;
    let cores = rng.range(1, 8);
    let n = rng.range(5, 120) as usize;
    let mut t = 0u64;
    let jobs: Vec<Job> = (0..n as u64)
        .map(|id| {
            t += rng.below(200);
            let runtime = rng.range(1, 2000);
            let est = runtime + rng.below(2000);
            Job::with_estimate(id + 1, t, rng.range(1, nodes as u64 * cores + 2), runtime, est)
        })
        .collect();
    Workload::new("prop", jobs, nodes, cores).drop_infeasible()
}

fn random_policy(rng: &mut Rng) -> Policy {
    Policy::ALL[rng.below(Policy::ALL.len() as u64) as usize]
}

/// Reconstruct core usage over time from the report and verify capacity
/// is never exceeded and every lifecycle timestamp is sane.
fn verify_lifecycle(r: &SimReport, capacity: u64, expected: usize) -> Result<(), String> {
    if r.completed.len() != expected {
        return Err(format!("completed {} != submitted {expected}", r.completed.len()));
    }
    let mut deltas: Vec<(SimTime, i64)> = Vec::new();
    for j in &r.completed {
        let start = j.start.ok_or_else(|| format!("job {} never started", j.id))?;
        let end = j.end.ok_or_else(|| format!("job {} never ended", j.id))?;
        if start < j.submit {
            return Err(format!("job {} started before submit", j.id));
        }
        if end.ticks() < start.ticks() + j.runtime.ticks() {
            return Err(format!("job {} ended early", j.id));
        }
        deltas.push((start, j.cores as i64));
        deltas.push((end, -(j.cores as i64)));
    }
    // Releases before acquisitions at equal times (completion frees first).
    deltas.sort_by_key(|&(t, d)| (t, d));
    let mut usage = 0i64;
    for (t, d) in deltas {
        usage += d;
        if usage > capacity as i64 {
            return Err(format!("capacity exceeded at {t}: {usage} > {capacity}"));
        }
        if usage < 0 {
            return Err(format!("negative usage at {t}"));
        }
    }
    Ok(())
}

#[test]
fn no_policy_oversubscribes_or_loses_jobs() {
    check_n("lifecycle+capacity", 120, |rng| {
        let w = random_workload(rng);
        let expected = w.jobs.len();
        let capacity = w.total_cores();
        let p = random_policy(rng);
        let r = run_policy(w, p);
        verify_lifecycle(&r, capacity, expected)
    });
}

#[test]
fn fcfs_starts_in_arrival_order() {
    check_n("fcfs order", 80, |rng| {
        let w = random_workload(rng);
        let r = run_policy(w, Policy::Fcfs);
        let mut jobs = r.completed.clone();
        jobs.sort_by_key(|j| (j.submit, j.id));
        // FCFS invariant: start times are non-decreasing in arrival order.
        for pair in jobs.windows(2) {
            if pair[1].start.unwrap() < pair[0].start.unwrap() {
                return Err(format!(
                    "job {} (arrived later) started before job {}",
                    pair[1].id, pair[0].id
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn backfill_never_delays_vs_fcfs_makespan_head() {
    // EASY property (observable form): under backfilling, the FCFS-order
    // start time of each job never gets *worse* for the blocked head
    // job at any scheduling point where estimates are exact. With exact
    // estimates (est == runtime) the backfill schedule's makespan is <=
    // FCFS's.
    check_n("easy no-harm", 60, |rng| {
        let mut w = random_workload(rng);
        for j in w.jobs.iter_mut() {
            j.est_runtime = j.runtime; // exact estimates
        }
        let fcfs = run_policy(w.clone(), Policy::Fcfs);
        let bf = run_policy(w, Policy::FcfsBackfill);
        if bf.end_time > fcfs.end_time {
            return Err(format!(
                "backfill makespan {} > fcfs {}",
                bf.end_time.ticks(),
                fcfs.end_time.ticks()
            ));
        }
        Ok(())
    });
}

#[test]
fn simulator_agrees_with_independent_baseline() {
    // The validation property behind Figs 3/4a, as a randomized law:
    // the component simulator and the flat CQsim-like baseline make
    // identical FCFS decisions on any workload.
    check_n("cross-simulator agreement", 60, |rng| {
        let w = random_workload(rng);
        let ours = run_policy(w.clone(), Policy::Fcfs);
        let base = sst_sched::baseline::run_baseline(&w, Policy::Fcfs);
        let key = |jobs: &[Job]| {
            let mut v: Vec<(u64, Option<SimTime>)> =
                jobs.iter().map(|j| (j.id, j.start)).collect();
            v.sort_unstable();
            v
        };
        if key(&ours.completed) != key(&base.completed) {
            return Err("independent simulators disagreed under FCFS".into());
        }
        Ok(())
    });
}

#[test]
fn deterministic_across_repeated_runs() {
    check_n("determinism", 40, |rng| {
        let w = random_workload(rng);
        let p = random_policy(rng);
        let a = run_policy(w.clone(), p);
        let b = run_policy(w, p);
        if a.events != b.events || a.end_time != b.end_time {
            return Err(format!("run differed: {}/{} vs {}/{}",
                a.events, a.end_time.ticks(), b.events, b.end_time.ticks()));
        }
        Ok(())
    });
}
