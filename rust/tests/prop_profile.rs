//! Property tests for the availability-timeline planning core
//! (`resources::profile::AvailabilityProfile`):
//!
//! * structural invariants survive random mutation sequences (strictly
//!   increasing breakpoint times, canonical form, capacity bound);
//! * incremental maintenance == from-scratch rebuild: laying the same
//!   holds one by one produces byte-identical breakpoints to sorting
//!   all deltas and folding them once (the resync path);
//! * hold/release pairs are exact inverses in any order;
//! * oracle: `earliest_slot` agrees with the O(segments^2) profile the
//!   conservative scheduler used before the refactor, on random release
//!   sets and after random reservations.

//! Multi-dimension additions (ResourceVector redesign): per-dimension
//! incremental == rebuild, vector hold/release exact inverses, and the
//! cores-only path bit-identical to the scalar profile. Plus the
//! fair-share ordering properties (determinism, monotone decay,
//! starvation recovery) — the other half of the planning-API redesign.

use sst_sched::core::rng::Rng;
use sst_sched::core::time::SimTime;
use sst_sched::resources::{AvailabilityProfile, ResourceVector};
use sst_sched::sched::{FairShare, QueueOrder};
use sst_sched::util::prop::check_n;

// ---------------------------------------------------------------------
// Oracle: the pre-refactor conservative-backfill profile, reproduced
// verbatim (breakpoint list, quadratic earliest_slot). The shared
// planner must make identical slot decisions on identical inputs.
// ---------------------------------------------------------------------

struct OracleProfile {
    points: Vec<(u64, u64)>,
}

impl OracleProfile {
    fn new(now: u64, free_now: u64, releases: &mut Vec<(u64, u64)>) -> OracleProfile {
        releases.sort_unstable();
        let mut points = vec![(now, free_now)];
        for &(t, c) in releases.iter() {
            let last = *points.last().unwrap();
            let t = t.max(now);
            if t == last.0 {
                points.last_mut().unwrap().1 = last.1 + c;
            } else {
                points.push((t, last.1 + c));
            }
        }
        OracleProfile { points }
    }

    fn earliest_slot(&self, from: u64, cores: u64, duration: u64) -> Option<u64> {
        let n = self.points.len();
        for i in 0..n {
            let (t_i, _) = self.points[i];
            let start = t_i.max(from);
            let end = start.saturating_add(duration);
            let ok = self.points.iter().enumerate().all(|(j, &(t_j, free_j))| {
                let seg_start = t_j;
                let seg_end = self.points.get(j + 1).map(|p| p.0).unwrap_or(u64::MAX);
                if seg_end <= start || seg_start >= end {
                    true
                } else {
                    free_j >= cores
                }
            });
            if ok {
                return Some(start);
            }
        }
        None
    }

    fn reserve(&mut self, start: u64, cores: u64, duration: u64) {
        let end = start.saturating_add(duration);
        self.split_at(start);
        self.split_at(end);
        for p in self.points.iter_mut() {
            if p.0 >= start && p.0 < end {
                assert!(p.1 >= cores, "oracle over-subscribed");
                p.1 -= cores;
            }
        }
    }

    fn split_at(&mut self, t: u64) {
        if t == u64::MAX {
            return;
        }
        match self.points.binary_search_by_key(&t, |p| p.0) {
            Ok(_) => {}
            Err(idx) => {
                if idx == 0 {
                    return;
                }
                let free = self.points[idx - 1].1;
                self.points.insert(idx, (t, free));
            }
        }
    }
}

fn random_releases(rng: &mut Rng) -> (u64, Vec<(u64, u64)>, u64) {
    let free_now = rng.range(0, 32);
    let n = rng.below(12);
    let releases: Vec<(u64, u64)> =
        (0..n).map(|_| (rng.range(0, 2_000), rng.range(1, 16))).collect();
    let total = free_now + releases.iter().map(|r| r.1).sum::<u64>();
    (free_now, releases, total)
}

#[test]
fn earliest_slot_matches_old_conservative_profile() {
    check_n("profile oracle", 400, |rng| {
        let (free_now, releases, total) = random_releases(rng);
        let profile = AvailabilityProfile::from_releases(0, free_now, total, &releases);
        let oracle = OracleProfile::new(0, free_now, &mut releases.clone());
        for _ in 0..24 {
            let cores = rng.range(1, total.max(1) + 4); // sometimes infeasible
            let duration = rng.range(1, 500);
            let from = rng.range(0, 2_500);
            let got = profile.earliest_slot(from, cores, duration);
            let want = oracle.earliest_slot(from, cores, duration);
            if got != want {
                return Err(format!(
                    "slot mismatch: from={from} cores={cores} dur={duration}: \
                     got {got:?}, oracle {want:?} (points {:?})",
                    profile.points()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn slots_match_oracle_after_reservations() {
    check_n("profile oracle with reservations", 200, |rng| {
        let (free_now, releases, total) = random_releases(rng);
        let mut profile = AvailabilityProfile::from_releases(0, free_now, total, &releases);
        let mut oracle = OracleProfile::new(0, free_now, &mut releases.clone());
        // Conservative-backfill workflow: find a slot, reserve it, repeat.
        for _ in 0..6 {
            if total == 0 {
                break;
            }
            let cores = rng.range(1, total);
            let duration = rng.range(1, 400);
            let from = rng.range(0, 1_000);
            let got = profile.earliest_slot(from, cores, duration);
            let want = oracle.earliest_slot(from, cores, duration);
            if got != want {
                return Err(format!(
                    "slot diverged after reservations: got {got:?}, oracle {want:?}"
                ));
            }
            let Some(start) = got else { continue };
            profile.hold(start, start.saturating_add(duration), cores);
            oracle.reserve(start, cores, duration);
            if !profile.check_invariants() {
                return Err(format!("invariants broken: {:?}", profile.points()));
            }
        }
        Ok(())
    });
}

#[test]
fn incremental_equals_from_scratch_rebuild() {
    check_n("incremental == rebuild", 300, |rng| {
        let free = rng.range(8, 64);
        let jobs: Vec<(u64, u64, u64)> = (0..rng.below(16))
            .map(|_| {
                let s = rng.range(0, 1_000);
                (s, s + rng.range(1, 500), rng.range(1, 8))
            })
            .collect();
        // Incremental: lay each hold on its own.
        let mut inc = AvailabilityProfile::new(0, free, free);
        for &(s, e, c) in &jobs {
            inc.hold(s, e, c);
        }
        // From scratch: fold all deltas at once (the resync path).
        let mut deltas = Vec::new();
        for &(s, e, c) in &jobs {
            deltas.push((s, -(c as i64)));
            deltas.push((e, c as i64));
        }
        let mut scratch = AvailabilityProfile::new(0, free, free);
        scratch.rebuild(0, free, deltas);
        if inc.points() != scratch.points() {
            return Err(format!(
                "incremental {:?} != rebuild {:?} (jobs {jobs:?})",
                inc.points(),
                scratch.points()
            ));
        }
        if !inc.check_invariants() {
            return Err(format!("invariants broken: {:?}", inc.points()));
        }
        Ok(())
    });
}

#[test]
fn hold_release_pairs_are_exact_inverses() {
    check_n("hold/release inverse", 300, |rng| {
        let free = rng.range(4, 64);
        let base = AvailabilityProfile::new(0, free, free);
        let mut p = base.clone();
        let mut ops: Vec<(u64, u64, u64)> = (0..rng.range(1, 20))
            .map(|_| {
                let s = rng.range(0, 1_500);
                (s, s + rng.range(1, 600), rng.range(1, 12))
            })
            .collect();
        for &(s, e, c) in &ops {
            p.hold(s, e, c);
        }
        // Release in shuffled order: the algebra must not care.
        rng.shuffle(&mut ops);
        for &(s, e, c) in &ops {
            p.release(s, e, c);
        }
        if p.points() != base.points() {
            return Err(format!("profile did not return to base: {:?}", p.points()));
        }
        Ok(())
    });
}

#[test]
fn advance_preserves_future_reads() {
    check_n("advance preserves future", 200, |rng| {
        let (free_now, releases, total) = random_releases(rng);
        let mut p = AvailabilityProfile::from_releases(0, free_now, total, &releases);
        let q = p.clone();
        let adv = rng.range(0, 2_500);
        p.advance(adv);
        if !p.check_invariants() {
            return Err(format!("invariants broken after advance: {:?}", p.points()));
        }
        for _ in 0..16 {
            let t = adv + rng.range(0, 1_000);
            if p.free_at(t) != q.free_at(t) {
                return Err(format!(
                    "free_at({t}) changed across advance({adv}): {} != {}",
                    p.free_at(t),
                    q.free_at(t)
                ));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Multi-dimension properties (ResourceVector redesign)
// ---------------------------------------------------------------------

fn random_vector_jobs(rng: &mut Rng) -> Vec<(u64, u64, ResourceVector)> {
    (0..rng.below(16))
        .map(|_| {
            let s = rng.range(0, 1_000);
            let e = s + rng.range(1, 500);
            // Roughly half the jobs carry memory (the mixed case).
            let mem = if rng.below(2) == 0 { rng.range(1, 2_000) } else { 0 };
            (s, e, ResourceVector::new(rng.range(1, 8), mem))
        })
        .collect()
}

#[test]
fn per_dimension_incremental_equals_rebuild() {
    check_n("vector incremental == rebuild", 300, |rng| {
        let free = ResourceVector::new(rng.range(8, 64), rng.range(4_000, 64_000));
        let jobs = random_vector_jobs(rng);
        // Incremental: lay each vector hold on its own.
        let mut inc = AvailabilityProfile::new_v(0, free, free);
        for &(s, e, d) in &jobs {
            inc.hold_v(s, e, d);
        }
        // From scratch: fold all per-dimension deltas at once (resync).
        let mut deltas = Vec::new();
        let mut mem_deltas = Vec::new();
        for &(s, e, d) in &jobs {
            deltas.push((s, -(d.cores as i64)));
            deltas.push((e, d.cores as i64));
            if d.memory_mb > 0 {
                mem_deltas.push((s, -(d.memory_mb as i64)));
                mem_deltas.push((e, d.memory_mb as i64));
            }
        }
        let mut scratch = AvailabilityProfile::new_v(0, free, free);
        scratch.rebuild_v(0, free, deltas, mem_deltas);
        if inc.points() != scratch.points() {
            return Err(format!(
                "cores dim: incremental {:?} != rebuild {:?}",
                inc.points(),
                scratch.points()
            ));
        }
        if inc.mem_points() != scratch.mem_points() {
            return Err(format!(
                "mem dim: incremental {:?} != rebuild {:?} (jobs {jobs:?})",
                inc.mem_points(),
                scratch.mem_points()
            ));
        }
        if !inc.check_invariants() {
            return Err("invariants broken".into());
        }
        Ok(())
    });
}

#[test]
fn vector_hold_release_pairs_are_exact_inverses() {
    check_n("vector hold/release inverse", 300, |rng| {
        let free = ResourceVector::new(rng.range(4, 64), rng.range(2_000, 32_000));
        let base = AvailabilityProfile::new_v(0, free, free);
        let mut p = base.clone();
        let mut ops = random_vector_jobs(rng);
        for &(s, e, d) in &ops {
            p.hold_v(s, e, d);
        }
        // Release in shuffled order: the algebra must not care.
        rng.shuffle(&mut ops);
        for &(s, e, d) in &ops {
            p.release_v(s, e, d);
        }
        if p.points() != base.points() {
            return Err(format!("cores dim did not return to base: {:?}", p.points()));
        }
        // The memory dimension (if it ever materialized) must read flat
        // at the base value everywhere.
        for _ in 0..16 {
            let t = rng.range(0, 3_000);
            if p.free_memory_at(t) != free.memory_mb {
                return Err(format!(
                    "mem dim did not return to base at t={t}: {} != {}",
                    p.free_memory_at(t),
                    free.memory_mb
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn cores_only_vector_path_is_bit_identical_to_scalar() {
    check_n("cores-only _v == scalar", 300, |rng| {
        let free = rng.range(8, 64);
        let ops: Vec<(u64, u64, u64, bool)> = (0..rng.below(20))
            .map(|_| {
                let s = rng.range(0, 1_500);
                (s, s + rng.range(1, 600), rng.range(1, 12), rng.below(2) == 0)
            })
            .collect();
        let mut scalar = AvailabilityProfile::new(0, free, free);
        // The vector profile TRACKS memory, but the workload carries no
        // memory demands — the lazy dimension must never materialize and
        // the cores dimension must be byte-identical.
        let mut vector = AvailabilityProfile::new_v(
            0,
            ResourceVector::new(free, 100_000),
            ResourceVector::new(free, 100_000),
        );
        for &(s, e, c, hold) in &ops {
            if hold {
                scalar.hold(s, e, c);
                vector.hold_v(s, e, ResourceVector::cores_only(c));
            } else {
                scalar.release(s, e, c);
                vector.release_v(s, e, ResourceVector::cores_only(c));
            }
        }
        if vector.has_memory_dimension() {
            return Err("memory dimension materialized on a cores-only workload".into());
        }
        if scalar.points() != vector.points() {
            return Err(format!(
                "cores dim diverged: scalar {:?} vector {:?}",
                scalar.points(),
                vector.points()
            ));
        }
        for _ in 0..16 {
            let from = rng.range(0, 2_000);
            let cores = rng.range(1, free + 4);
            let dur = rng.range(1, 400);
            let d = ResourceVector::cores_only(cores);
            if scalar.earliest_slot(from, cores, dur) != vector.earliest_slot_v(from, d, dur) {
                return Err("earliest_slot diverged on cores-only demand".into());
            }
            if scalar.can_place(from, dur, cores) != vector.can_place_v(from, dur, d) {
                return Err("can_place diverged on cores-only demand".into());
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Fair-share ordering properties (queue-ordering seam)
// ---------------------------------------------------------------------

#[test]
fn fairshare_is_deterministic_and_order_preserving() {
    check_n("fair-share determinism", 200, |rng| {
        let half_life = rng.range(100, 10_000);
        let mut a = FairShare::new(half_life);
        let mut b = FairShare::new(half_life);
        let events: Vec<(u32, u32, u64, u64, u64)> = (0..rng.range(1, 30))
            .map(|_| {
                (
                    rng.below(6) as u32,
                    rng.below(3) as u32,
                    rng.range(1, 32),
                    rng.range(1, 5_000),
                    rng.range(0, 50_000),
                )
            })
            .collect();
        let mut times: Vec<u64> = events.iter().map(|e| e.4).collect();
        times.sort_unstable();
        for (&(user, group, cores, secs, _), &t) in events.iter().zip(&times) {
            a.record_usage(user, group, cores, secs, SimTime(t));
            b.record_usage(user, group, cores, secs, SimTime(t));
        }
        let now = SimTime(times.last().copied().unwrap_or(0) + rng.range(0, 10_000));
        // Identical histories => identical snapshots, bit for bit.
        let (sa, sb) = (a.usage_snapshot(now), b.usage_snapshot(now));
        if sa.len() != sb.len()
            || sa.iter().zip(&sb).any(|(x, y)| {
                x.user != y.user || x.group != y.group || x.usage.to_bits() != y.usage.to_bits()
            })
        {
            return Err("identical usage histories diverged".into());
        }
        // Decay never changes the relative order of two users' usage
        // (same decay factor law), so fair-share never flip-flops
        // between rounds without new usage.
        // Stay within ~20 half-lives so values keep full float precision
        // (deeper decay drifts into subnormals where ordering noise is
        // expected and meaningless).
        let later = SimTime(now.ticks() + rng.range(1, 20 * half_life));
        let s2 = a.usage_snapshot(later);
        for (x, y) in sa.iter().zip(sa.iter().skip(1)) {
            // Decay multiplies every user by the same 2^{-t/h} law, so
            // clearly-separated usages can never swap sides (near-ties
            // are excused: float rounding may order them either way).
            let clearly_apart = (x.usage - y.usage).abs()
                > 1e-9 * x.usage.abs().max(y.usage.abs()).max(1.0);
            let x2 = s2.iter().find(|s| (s.user, s.group) == (x.user, x.group)).unwrap();
            let y2 = s2.iter().find(|s| (s.user, s.group) == (y.user, y.group)).unwrap();
            if clearly_apart && (x.usage < y.usage) != (x2.usage <= y2.usage) {
                return Err(format!(
                    "relative order flipped under pure decay: {x:?}/{y:?} -> {x2:?}/{y2:?}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn fairshare_starvation_decay_recovers_heavy_users() {
    check_n("fair-share starvation decay", 200, |rng| {
        let half_life = rng.range(100, 5_000);
        let mut fs = FairShare::new(half_life);
        let charged = rng.range(1, 64) * rng.range(1, 3_600);
        fs.record_usage(1, 0, 1, charged, SimTime(0));
        // Decayed usage is monotone non-increasing in time...
        let mut last = f64::INFINITY;
        for k in 0..12 {
            let u = fs.effective_usage(1, 0, SimTime(k * half_life));
            if u > last + 1e-9 {
                return Err(format!("usage rose under decay: {u} > {last}"));
            }
            last = u;
        }
        // ...halves every half-life...
        let one = fs.effective_usage(1, 0, SimTime(half_life));
        let expect = charged as f64 / 2.0;
        if (one - expect).abs() > 1e-6 * expect.max(1.0) {
            return Err(format!("half-life decay wrong: {one} vs {expect}"));
        }
        // ...and after 60 half-lives the penalty is gone for practical
        // purposes: the once-greedy user cannot be starved forever.
        let cold = fs.effective_usage(1, 0, SimTime(60 * half_life));
        if cold > charged as f64 * 1e-15 {
            return Err(format!("penalty never fades: {cold}"));
        }
        Ok(())
    });
}

#[test]
fn capacity_windows_round_trip() {
    check_n("capacity windows", 200, |rng| {
        let free = rng.range(4, 64);
        let mut p = AvailabilityProfile::new(0, free, free);
        let start = rng.range(0, 1_000);
        let end = start + rng.range(1, 1_000);
        let cores = rng.range(1, 96); // may over-commit on purpose
        p.add_reservation_hold(start, end, cores);
        // Reads clamp; the window offers no more than what was free.
        if p.free_at(start) != free.saturating_sub(cores) {
            return Err(format!(
                "window read wrong: {} != {}",
                p.free_at(start),
                free.saturating_sub(cores)
            ));
        }
        if p.free_at(end) != free {
            return Err("capacity did not return after the window".into());
        }
        p.restore_node_capacity(start, end, cores);
        if p.points() != AvailabilityProfile::new(0, free, free).points() {
            return Err("window removal did not restore the base profile".into());
        }
        Ok(())
    });
}
