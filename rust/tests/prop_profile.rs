//! Property tests for the availability-timeline planning core
//! (`resources::profile::AvailabilityProfile`):
//!
//! * structural invariants survive random mutation sequences (strictly
//!   increasing breakpoint times, canonical form, capacity bound);
//! * incremental maintenance == from-scratch rebuild: laying the same
//!   holds one by one produces byte-identical breakpoints to sorting
//!   all deltas and folding them once (the resync path);
//! * hold/release pairs are exact inverses in any order;
//! * oracle: `earliest_slot` agrees with the O(segments^2) profile the
//!   conservative scheduler used before the refactor, on random release
//!   sets and after random reservations.

use sst_sched::core::rng::Rng;
use sst_sched::resources::AvailabilityProfile;
use sst_sched::util::prop::check_n;

// ---------------------------------------------------------------------
// Oracle: the pre-refactor conservative-backfill profile, reproduced
// verbatim (breakpoint list, quadratic earliest_slot). The shared
// planner must make identical slot decisions on identical inputs.
// ---------------------------------------------------------------------

struct OracleProfile {
    points: Vec<(u64, u64)>,
}

impl OracleProfile {
    fn new(now: u64, free_now: u64, releases: &mut Vec<(u64, u64)>) -> OracleProfile {
        releases.sort_unstable();
        let mut points = vec![(now, free_now)];
        for &(t, c) in releases.iter() {
            let last = *points.last().unwrap();
            let t = t.max(now);
            if t == last.0 {
                points.last_mut().unwrap().1 = last.1 + c;
            } else {
                points.push((t, last.1 + c));
            }
        }
        OracleProfile { points }
    }

    fn earliest_slot(&self, from: u64, cores: u64, duration: u64) -> Option<u64> {
        let n = self.points.len();
        for i in 0..n {
            let (t_i, _) = self.points[i];
            let start = t_i.max(from);
            let end = start.saturating_add(duration);
            let ok = self.points.iter().enumerate().all(|(j, &(t_j, free_j))| {
                let seg_start = t_j;
                let seg_end = self.points.get(j + 1).map(|p| p.0).unwrap_or(u64::MAX);
                if seg_end <= start || seg_start >= end {
                    true
                } else {
                    free_j >= cores
                }
            });
            if ok {
                return Some(start);
            }
        }
        None
    }

    fn reserve(&mut self, start: u64, cores: u64, duration: u64) {
        let end = start.saturating_add(duration);
        self.split_at(start);
        self.split_at(end);
        for p in self.points.iter_mut() {
            if p.0 >= start && p.0 < end {
                assert!(p.1 >= cores, "oracle over-subscribed");
                p.1 -= cores;
            }
        }
    }

    fn split_at(&mut self, t: u64) {
        if t == u64::MAX {
            return;
        }
        match self.points.binary_search_by_key(&t, |p| p.0) {
            Ok(_) => {}
            Err(idx) => {
                if idx == 0 {
                    return;
                }
                let free = self.points[idx - 1].1;
                self.points.insert(idx, (t, free));
            }
        }
    }
}

fn random_releases(rng: &mut Rng) -> (u64, Vec<(u64, u64)>, u64) {
    let free_now = rng.range(0, 32);
    let n = rng.below(12);
    let releases: Vec<(u64, u64)> =
        (0..n).map(|_| (rng.range(0, 2_000), rng.range(1, 16))).collect();
    let total = free_now + releases.iter().map(|r| r.1).sum::<u64>();
    (free_now, releases, total)
}

#[test]
fn earliest_slot_matches_old_conservative_profile() {
    check_n("profile oracle", 400, |rng| {
        let (free_now, releases, total) = random_releases(rng);
        let profile = AvailabilityProfile::from_releases(0, free_now, total, &releases);
        let oracle = OracleProfile::new(0, free_now, &mut releases.clone());
        for _ in 0..24 {
            let cores = rng.range(1, total.max(1) + 4); // sometimes infeasible
            let duration = rng.range(1, 500);
            let from = rng.range(0, 2_500);
            let got = profile.earliest_slot(from, cores, duration);
            let want = oracle.earliest_slot(from, cores, duration);
            if got != want {
                return Err(format!(
                    "slot mismatch: from={from} cores={cores} dur={duration}: \
                     got {got:?}, oracle {want:?} (points {:?})",
                    profile.points()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn slots_match_oracle_after_reservations() {
    check_n("profile oracle with reservations", 200, |rng| {
        let (free_now, releases, total) = random_releases(rng);
        let mut profile = AvailabilityProfile::from_releases(0, free_now, total, &releases);
        let mut oracle = OracleProfile::new(0, free_now, &mut releases.clone());
        // Conservative-backfill workflow: find a slot, reserve it, repeat.
        for _ in 0..6 {
            if total == 0 {
                break;
            }
            let cores = rng.range(1, total);
            let duration = rng.range(1, 400);
            let from = rng.range(0, 1_000);
            let got = profile.earliest_slot(from, cores, duration);
            let want = oracle.earliest_slot(from, cores, duration);
            if got != want {
                return Err(format!(
                    "slot diverged after reservations: got {got:?}, oracle {want:?}"
                ));
            }
            let Some(start) = got else { continue };
            profile.hold(start, start.saturating_add(duration), cores);
            oracle.reserve(start, cores, duration);
            if !profile.check_invariants() {
                return Err(format!("invariants broken: {:?}", profile.points()));
            }
        }
        Ok(())
    });
}

#[test]
fn incremental_equals_from_scratch_rebuild() {
    check_n("incremental == rebuild", 300, |rng| {
        let free = rng.range(8, 64);
        let jobs: Vec<(u64, u64, u64)> = (0..rng.below(16))
            .map(|_| {
                let s = rng.range(0, 1_000);
                (s, s + rng.range(1, 500), rng.range(1, 8))
            })
            .collect();
        // Incremental: lay each hold on its own.
        let mut inc = AvailabilityProfile::new(0, free, free);
        for &(s, e, c) in &jobs {
            inc.hold(s, e, c);
        }
        // From scratch: fold all deltas at once (the resync path).
        let mut deltas = Vec::new();
        for &(s, e, c) in &jobs {
            deltas.push((s, -(c as i64)));
            deltas.push((e, c as i64));
        }
        let mut scratch = AvailabilityProfile::new(0, free, free);
        scratch.rebuild(0, free, deltas);
        if inc.points() != scratch.points() {
            return Err(format!(
                "incremental {:?} != rebuild {:?} (jobs {jobs:?})",
                inc.points(),
                scratch.points()
            ));
        }
        if !inc.check_invariants() {
            return Err(format!("invariants broken: {:?}", inc.points()));
        }
        Ok(())
    });
}

#[test]
fn hold_release_pairs_are_exact_inverses() {
    check_n("hold/release inverse", 300, |rng| {
        let free = rng.range(4, 64);
        let base = AvailabilityProfile::new(0, free, free);
        let mut p = base.clone();
        let mut ops: Vec<(u64, u64, u64)> = (0..rng.range(1, 20))
            .map(|_| {
                let s = rng.range(0, 1_500);
                (s, s + rng.range(1, 600), rng.range(1, 12))
            })
            .collect();
        for &(s, e, c) in &ops {
            p.hold(s, e, c);
        }
        // Release in shuffled order: the algebra must not care.
        rng.shuffle(&mut ops);
        for &(s, e, c) in &ops {
            p.release(s, e, c);
        }
        if p.points() != base.points() {
            return Err(format!("profile did not return to base: {:?}", p.points()));
        }
        Ok(())
    });
}

#[test]
fn advance_preserves_future_reads() {
    check_n("advance preserves future", 200, |rng| {
        let (free_now, releases, total) = random_releases(rng);
        let mut p = AvailabilityProfile::from_releases(0, free_now, total, &releases);
        let q = p.clone();
        let adv = rng.range(0, 2_500);
        p.advance(adv);
        if !p.check_invariants() {
            return Err(format!("invariants broken after advance: {:?}", p.points()));
        }
        for _ in 0..16 {
            let t = adv + rng.range(0, 1_000);
            if p.free_at(t) != q.free_at(t) {
                return Err(format!(
                    "free_at({t}) changed across advance({adv}): {} != {}",
                    p.free_at(t),
                    q.free_at(t)
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn capacity_windows_round_trip() {
    check_n("capacity windows", 200, |rng| {
        let free = rng.range(4, 64);
        let mut p = AvailabilityProfile::new(0, free, free);
        let start = rng.range(0, 1_000);
        let end = start + rng.range(1, 1_000);
        let cores = rng.range(1, 96); // may over-commit on purpose
        p.add_reservation_hold(start, end, cores);
        // Reads clamp; the window offers no more than what was free.
        if p.free_at(start) != free.saturating_sub(cores) {
            return Err(format!(
                "window read wrong: {} != {}",
                p.free_at(start),
                free.saturating_sub(cores)
            ));
        }
        if p.free_at(end) != free {
            return Err("capacity did not return after the window".into());
        }
        p.restore_node_capacity(start, end, cores);
        if p.points() != AvailabilityProfile::new(0, free, free).points() {
            return Err("window removal did not restore the base profile".into());
        }
        Ok(())
    });
}
