//! Integration tests for the sharded multi-domain federation engine:
//! the shard-count determinism matrix (the PR's headline contract),
//! router/batch equivalence, and the faults + reservations + routing
//! composition test.

use sst_sched::job::Job;
use sst_sched::parallel::{fnv1a, run_sharded, RankSimOpts, ShardOpts};
use sst_sched::sched::Policy;
use sst_sched::sim::{FaultConfig, MetaScheduler, ReservationSpec, Routing};
use sst_sched::trace::Das2Model;

fn federation_opts(routing: Routing, shards: usize) -> ShardOpts {
    ShardOpts {
        clusters: MetaScheduler::das2_federation(routing, Policy::FcfsBackfill).clusters,
        routing,
        policy: Policy::FcfsBackfill,
        shards,
        route_latency: 60,
        sim: RankSimOpts::default(),
    }
}

fn jobs(n: usize, seed: u64) -> Vec<Job> {
    Das2Model::default().generate(n, seed).scale_arrivals(0.3).jobs
}

#[test]
fn shard_count_matrix_produces_identical_fingerprints() {
    // The tentpole contract: parallel decisions == serial decisions,
    // byte for byte, for every shard count (8 clamps to the 5 domains).
    let js = jobs(2_500, 11);
    let base = run_sharded(&federation_opts(Routing::LeastLoaded, 1), js.clone(), false);
    assert!(base.total_completed() > 0);
    for shards in [1usize, 2, 4, 8] {
        let threaded =
            run_sharded(&federation_opts(Routing::LeastLoaded, shards), js.clone(), true);
        let modeled =
            run_sharded(&federation_opts(Routing::LeastLoaded, shards), js.clone(), false);
        assert_eq!(
            threaded.fingerprint(),
            base.fingerprint(),
            "threaded {shards}-shard decisions diverged from serial"
        );
        assert_eq!(
            modeled.fingerprint(),
            base.fingerprint(),
            "modeled {shards}-shard decisions diverged from serial"
        );
        // The window sequence is a function of event times alone, so it
        // is shard-count independent too.
        assert_eq!(threaded.windows, base.windows, "shards={shards}");
        assert_eq!(threaded.total_completed(), base.total_completed(), "shards={shards}");
        assert_eq!(threaded.rejected, base.rejected, "shards={shards}");
        assert_eq!(threaded.router_fingerprint, base.router_fingerprint, "shards={shards}");
    }
}

#[test]
fn router_decisions_match_the_batch_meta_scheduler() {
    // The in-window router must make exactly the decisions the batch
    // `MetaScheduler::route` makes on the submit-sorted trace — same
    // state machine, fed incrementally.
    for routing in [Routing::RoundRobin, Routing::LeastLoaded, Routing::BestFitCluster] {
        let mut js = jobs(1_200, 12);
        js.sort_by_key(|j| j.submit);
        let m = MetaScheduler::das2_federation(routing, Policy::FcfsBackfill);
        let routes = m.route(&js);
        let mut expected_fp = Vec::new();
        let mut expected_routed = 0u64;
        let mut expected_rejected = 0u64;
        for (j, r) in js.iter().zip(&routes) {
            match r {
                Some(dom) => {
                    expected_routed += 1;
                    expected_fp.extend_from_slice(&j.id.to_le_bytes());
                    expected_fp.extend_from_slice(&(*dom as u64).to_le_bytes());
                }
                None => expected_rejected += 1,
            }
        }
        let rep = run_sharded(&federation_opts(routing, 4), js, true);
        assert_eq!(rep.routed, expected_routed, "{routing:?}");
        assert_eq!(rep.rejected, expected_rejected, "{routing:?}");
        assert_eq!(rep.router_fingerprint, fnv1a(&expected_fp), "{routing:?}");
    }
}

#[test]
fn faults_and_reservations_compose_on_the_sharded_engine() {
    // Federation run where every domain injects failures and holds a
    // reservation window: the composition must stay deterministic
    // across shard counts, and both subsystems must actually fire.
    let mut opts = federation_opts(Routing::LeastLoaded, 1);
    opts.sim.faults = FaultConfig { mtbf: 2_000.0, mttr: 600.0, ..FaultConfig::default() };
    opts.sim.reservations = vec![ReservationSpec { start: 2_000, duration: 4_000, nodes: 8 }];
    let js = jobs(1_500, 13);
    let n = js.len() as u64;
    let serial = run_sharded(&opts, js.clone(), false);
    let mut opts4 = opts.clone();
    opts4.shards = 4;
    let sharded = run_sharded(&opts4, js, true);
    assert_eq!(sharded.fingerprint(), serial.fingerprint());
    let failures: u64 = sharded.domains.iter().map(|d| d.report.faults.failures).sum();
    let resv: u64 =
        sharded.domains.iter().map(|d| d.report.faults.reservations_started).sum();
    assert!(failures > 0, "fault injection never fired on the sharded engine");
    assert!(resv > 0, "reservations never started on the sharded engine");
    assert_eq!(sharded.total_completed() + sharded.rejected, n);
}

#[test]
fn meta_scheduler_run_rides_the_sharded_engine() {
    // `MetaScheduler::run` is now a 1-shard sharded run; a 4-shard run
    // with the same route latency must reproduce its fingerprint.
    let js = jobs(1_000, 14);
    let m = MetaScheduler::das2_federation(Routing::BestFitCluster, Policy::FcfsBackfill);
    let legacy = m.run(&js);
    let mut opts = federation_opts(Routing::BestFitCluster, 4);
    opts.route_latency = 1; // MetaScheduler::run's latency
    let sharded = run_sharded(&opts, js, true);
    assert_eq!(sharded.fingerprint(), legacy.fingerprint);
    assert_eq!(
        sharded.total_completed(),
        legacy.all_jobs.len() as u64,
        "same completions either way"
    );
}
