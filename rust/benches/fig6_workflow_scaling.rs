//! Bench: regenerate paper Fig 6 (parallel scaling of the workflow
//! simulator on the Galactic Plane workflow, with real cross-rank
//! dependency messages). Modeled PDES wall times — see fig5_scaling.rs.

use sst_sched::harness::{fig6_wide, print_fig5};

fn main() {
    println!("Fig 6: Galactic Plane workflow scaling (17 surveys x 256 tiles)\n");
    let rows = fig6_wide(17, 256, &[1, 2, 4, 8], 1);
    print_fig5(&rows);
    assert!(rows[0].speedup == 1.0);
    assert!(
        rows.last().unwrap().speedup > 1.5,
        "workflow simulation should scale: got {:.2}x at 8 ranks",
        rows.last().unwrap().speedup
    );
    // All rank counts simulate the same DAG.
    assert!(rows.iter().all(|r| r.jobs == rows[0].jobs));

    println!("smaller instance (17 x 64) for the overhead-dominated regime:\n");
    let rows = fig6_wide(17, 64, &[1, 2, 4, 8], 1);
    print_fig5(&rows);
}
