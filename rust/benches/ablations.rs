//! Ablation benches for the design choices DESIGN.md calls out:
//! EASY aging/waste weights, conservative vs EASY backfilling,
//! conservative-window lookahead, workflow task ordering + preemption,
//! multi-cluster routing, and topology-aware slowdown sensitivity.

use sst_sched::parallel::run_jobs_parallel_modeled;
use sst_sched::resources::Topology;
use sst_sched::sched::{BackfillScheduler, Policy};
use sst_sched::sim::{run_policy, MetaScheduler, Routing, Simulation};
use sst_sched::trace::Das2Model;
use sst_sched::util::table::{f, Table};
use sst_sched::workflow::generators::{epigenomics, montage, sipht};
use sst_sched::workflow::{DynamicExecutor, TaskOrder};

fn main() {
    let workload =
        Das2Model::default().generate(8_000, 5).scale_arrivals(0.45).drop_infeasible();

    println!("=== ablation: EASY scoring weights (aging, waste) ===");
    let mut t = Table::new(&["aging", "waste", "mean wait (s)", "p95 (s)"]);
    for (aging, waste) in [(0.0, 0.0), (1.0, 0.0), (0.0, 0.5), (1.0, 0.5), (4.0, 0.5), (1.0, 4.0)]
    {
        let mut sched = BackfillScheduler::new();
        sched.aging_weight = aging;
        sched.waste_weight = waste;
        let r = Simulation::new(workload.clone(), Policy::FcfsBackfill)
            .with_scheduler(Box::new(sched))
            .run(None);
        let s = r.wait_stats();
        t.row(&[f(aging as f64), f(waste as f64), f(s.mean_wait), f(s.p95_wait)]);
    }
    t.print();

    println!("\n=== ablation: EASY vs conservative backfilling ===");
    let mut t = Table::new(&["policy", "mean wait (s)", "p95 (s)", "slowdown"]);
    for p in [Policy::Fcfs, Policy::FcfsBackfill, Policy::ConservativeBackfill] {
        let r = run_policy(workload.clone(), p);
        let s = r.wait_stats();
        t.row(&[p.to_string(), f(s.mean_wait), f(s.p95_wait), f(s.mean_slowdown)]);
    }
    t.print();

    println!("\n=== ablation: conservative-window lookahead (4 ranks, 50k jobs) ===");
    let big = Das2Model::default().generate(50_000, 1).drop_infeasible();
    let mut t = Table::new(&["lookahead (s)", "windows", "modeled wall (ms)"]);
    for lookahead in [600u64, 3_600, 21_600, 86_400, 345_600] {
        let rep = run_jobs_parallel_modeled(&big, Policy::FcfsBackfill, 4, lookahead);
        t.row(&[
            lookahead.to_string(),
            rep.windows.to_string(),
            format!("{:.1}", rep.wall.as_secs_f64() * 1e3),
        ]);
    }
    t.print();

    println!("\n=== ablation: workflow task ordering (8-cpu pool) ===");
    let mut t = Table::new(&["workflow", "fcfs (s)", "critical-path (s)", "widest (s)", "cp+preempt (s)"]);
    for w in [montage(64, 1, true), sipht(4, 1, true), epigenomics(4, 8, 1, true)] {
        let ms = |order: TaskOrder, pre: bool| {
            let mut ex = DynamicExecutor::new(8, order);
            if pre {
                ex = ex.with_preemption();
            }
            ex.run(w.clone()).makespan.ticks().to_string()
        };
        t.row(&[
            w.name.clone(),
            ms(TaskOrder::Fcfs, false),
            ms(TaskOrder::CriticalPath, false),
            ms(TaskOrder::WidestFirst, false),
            ms(TaskOrder::CriticalPath, true),
        ]);
    }
    t.print();

    println!("\n=== ablation: multi-cluster routing (DAS-2 federation) ===");
    let jobs = Das2Model::default().generate(6_000, 3).scale_arrivals(0.3).jobs;
    let mut t = Table::new(&["routing", "mean wait (s)", "p95 (s)", "rejected"]);
    for routing in [Routing::RoundRobin, Routing::LeastLoaded, Routing::BestFitCluster] {
        let rep = MetaScheduler::das2_federation(routing, Policy::FcfsBackfill).run(&jobs);
        let s = rep.wait_stats();
        t.row(&[
            format!("{routing:?}"),
            f(s.mean_wait),
            f(s.p95_wait),
            rep.rejected.to_string(),
        ]);
    }
    t.print();

    println!("\n=== ablation: topology slowdown sensitivity (first-fit spread) ===");
    // Allocate a 16-node job on each topology as nodes 0..16 (contiguous
    // first-fit) vs a scattered stride-4 placement; report slowdowns.
    let alloc = |ids: Vec<usize>| sst_sched::resources::Allocation {
        job_id: 1,
        taken: ids.into_iter().map(|n| (n, 1, 0)).collect(),
    };
    let contiguous = alloc((0..16).collect());
    let scattered = alloc((0..16).map(|i| i * 4).collect());
    let mut t = Table::new(&["topology", "span contig", "span scatter", "slowdown@0.1 scatter"]);
    for topo in [
        Topology::Mesh2D { x: 8, y: 8 },
        Topology::Torus2D { x: 8, y: 8 },
        Topology::FatTree { leaf: 4, agg: 4 },
        Topology::Dragonfly { a: 4, p: 4 },
    ] {
        t.row(&[
            format!("{topo:?}"),
            f(topo.allocation_span(&contiguous.node_ids())),
            f(topo.allocation_span(&scattered.node_ids())),
            format!("{:.2}x", topo.slowdown(&scattered, 0.1)),
        ]);
    }
    t.print();
}
