//! Bench: regenerate paper Fig 5 (parallel scaling of the job simulator
//! on DAS-2-like and SDSC-SP2-like workloads across ranks and scales).
//!
//! Wall times are *modeled* conservative-PDES times (this container
//! exposes one CPU): per-rank window times are measured serially and the
//! reported wall is the window critical path + barrier costs. See
//! `parallel::run_parallel_modeled` and DESIGN.md §Substitutions.

use sst_sched::harness::{fig5, print_fig5};

fn main() {
    println!("Fig 5(a): DAS-2-like, ranks 1-8, three job scales\n");
    let rows = fig5(false, &[20_000, 50_000, 200_000], &[1, 2, 4, 8], 1);
    print_fig5(&rows);
    // Shape assertions: speedup grows with ranks at the largest scale,
    // and the largest scale speeds up at least as well as the smallest
    // ("as the job sizes increased, we achieve greater speedup").
    let at = |jobs: usize, ranks: usize| {
        rows.iter().find(|r| r.jobs == jobs && r.ranks == ranks).unwrap().speedup
    };
    assert!(at(200_000, 8) > at(200_000, 2), "speedup should grow with ranks");
    assert!(
        at(200_000, 8) >= at(20_000, 8) * 0.8,
        "larger workloads should scale at least comparably"
    );

    println!("Fig 5(b): SDSC-SP2-like, ranks 1-8\n");
    let rows = fig5(true, &[50_000], &[1, 2, 4, 8], 1);
    print_fig5(&rows);
    assert!(
        rows.last().unwrap().speedup > rows[1].speedup * 0.8,
        "SP2 scaling should not collapse at 8 ranks"
    );
}
