//! Bench: regenerate paper Fig 4 (wait-time validation + the five-policy
//! comparison) and time one full simulation per policy.

use sst_sched::harness::{fig4a, fig4b, print_fig4a, print_fig4b};
use sst_sched::sched::Policy;
use sst_sched::sim::run_policy;
use sst_sched::trace::Das2Model;
use sst_sched::util::bench::{section, Bench};

fn main() {
    section("Fig 4(a): wait-time validation vs CQsim-like (10k jobs)");
    let v = fig4a(10_000, 1, 20);
    print_fig4a(&v);
    assert!(v.correlation > 0.9, "validation regressed: corr {}", v.correlation);

    section("Fig 4(b): five scheduling algorithms (8k jobs, high load)");
    let rows = fig4b(8_000, 1);
    print_fig4b(&rows);
    let wait = |n: &str| rows.iter().find(|r| r.policy == n).unwrap().mean_wait;
    assert!(wait("fcfs-backfill") <= wait("fcfs"), "backfill should beat FCFS");
    assert!(wait("sjf") <= wait("ljf"), "SJF should beat LJF");

    section("timing: one full 10k-job simulation per policy");
    let w = Das2Model::default().generate(10_000, 1).scale_arrivals(0.45).drop_infeasible();
    let mut b = Bench::new(1, 5);
    for p in Policy::ALL {
        let w = w.clone();
        b.case(&format!("sim/das2-10k/{p}"), move || run_policy(w.clone(), p).events);
    }
}
