//! Bench: regenerate paper Fig 3 (occupancy + running-jobs validation
//! vs the CQsim-like baseline) and time the two simulators.

use sst_sched::harness::{fig3a, fig3b, print_validation};
use sst_sched::util::bench::{section, Bench};

fn main() {
    section("Fig 3(a): node occupancy over time (DAS-2-like, 10k jobs)");
    let v = fig3a(10_000, 1, 24);
    print_validation(&v);
    assert!(v.correlation > 0.9, "validation regressed: corr {}", v.correlation);

    section("Fig 3(b): running jobs over time");
    let v = fig3b(10_000, 1, 24);
    print_validation(&v);
    assert!(v.correlation > 0.9, "validation regressed: corr {}", v.correlation);

    section("timing");
    let mut b = Bench::new(1, 5);
    b.case("fig3a/10k-jobs (sim + baseline)", || fig3a(10_000, 1, 24));
    b.case("fig3b/10k-jobs (sim + baseline)", || fig3b(10_000, 1, 24));
}
