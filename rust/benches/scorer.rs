//! Bench: queue-scorer backends — pure-Rust vs the AOT-compiled
//! JAX/Pallas artifact on PJRT (the L1/L2 hot-path numbers for
//! EXPERIMENTS.md §Perf).
//!
//! Requires `make artifacts` for the XLA cases; they are skipped with a
//! notice when the artifact is missing.

use sst_sched::sched::scorer::{NativeScorer, QueueScorer, ScoreParams};
#[cfg(feature = "xla")]
use sst_sched::runtime::XlaScorer;
use sst_sched::util::bench::{section, Bench};

fn inputs(q: usize, n: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let req: Vec<f32> = (0..q).map(|i| (i % 17 + 1) as f32).collect();
    let est: Vec<f32> = (0..q).map(|i| 60.0 * (1 + i % 23) as f32).collect();
    let wait: Vec<f32> = (0..q).map(|i| (i % 700) as f32).collect();
    let free: Vec<f32> = (0..n).map(|i| (i % 3) as f32).collect();
    (req, est, wait, free)
}

fn params() -> ScoreParams {
    ScoreParams { shadow_time: 600.0, extra_cores: 16.0, aging_weight: 1.0, waste_weight: 0.5 }
}

fn main() {
    let mut b = Bench::new(3, 10);

    section("native scorer (pure Rust)");
    for (q, n) in [(32usize, 72usize), (256, 512), (1024, 512)] {
        let (req, est, wait, free) = inputs(q, n);
        let mut s = NativeScorer::new();
        b.case(&format!("native/q{q}/n{n}"), move || {
            s.score(&req, &est, &wait, &free, params()).priority.len()
        });
    }

    section("XLA scorer (AOT JAX + Pallas via PJRT)");
    #[cfg(not(feature = "xla"))]
    println!("skipped: built without the `xla` feature");
    #[cfg(feature = "xla")]
    match XlaScorer::load_default() {
        Err(e) => println!("skipped: {e:#} (run `make artifacts`)"),
        Ok(_) => {
            for (q, n) in [(32usize, 72usize), (256, 512), (1024, 512)] {
                let (req, est, wait, free) = inputs(q, n);
                let mut s = XlaScorer::load_default().unwrap();
                b.case(&format!("xla/q{q}/n{n}"), move || {
                    s.score(&req, &est, &wait, &free, params()).priority.len()
                });
            }
            // Compile cost (once per process in production).
            b.case("xla/load+compile", || XlaScorer::load_default().is_ok());
        }
    }
}
