//! Bench: regenerate paper Fig 7 (SIPHT workflow wait-time validation:
//! simulator with sampled runtimes vs the exact published profile).

use sst_sched::harness::{fig7, print_fig7};
use sst_sched::util::bench::{section, Bench};
use sst_sched::workflow::generators::sipht;
use sst_sched::workflow::WorkflowExecutor;

fn main() {
    section("Fig 7: SIPHT wait-time validation (4 replicons, 8-cpu pool)");
    let v = fig7(4, 8, 1);
    print_fig7(&v);
    let ratio = v.ours_makespan as f64 / v.ref_makespan as f64;
    assert!((0.7..1.3).contains(&ratio), "makespan diverged: ratio {ratio}");

    section("sensitivity: pool widths");
    for cpu in [4u64, 8, 16, 32] {
        let v = fig7(4, cpu, 1);
        println!(
            "cpu={cpu:<3} MAE {:>8.2} s   makespan ref {:>6} s ours {:>6} s",
            v.mae, v.ref_makespan, v.ours_makespan
        );
    }

    section("timing");
    let mut b = Bench::new(1, 5);
    b.case("sipht-4/exec-8cpu", || {
        WorkflowExecutor::new(8, u64::MAX).run(sipht(4, 1, false)).makespan
    });
    b.case("fig7/full-validation", || fig7(4, 8, 1).mae);
}
