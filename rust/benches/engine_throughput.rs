//! Bench: core engine + simulator throughput (events/second) — the L3
//! hot-path numbers tracked in EXPERIMENTS.md §Perf.

use sst_sched::baseline::run_baseline;
use sst_sched::sched::Policy;
use sst_sched::sim::run_policy;
use sst_sched::trace::{Das2Model, SdscSp2Model};
use sst_sched::util::bench::{section, Bench};

fn main() {
    section("event-driven simulator throughput");
    let das2 = Das2Model::default().generate(100_000, 1).drop_infeasible();
    let sp2 = SdscSp2Model::default().generate(50_000, 1).drop_infeasible();
    let mut b = Bench::new(1, 5);

    let w = das2.clone();
    let r = b.case("sim/das2-100k/fcfs", move || run_policy(w.clone(), Policy::Fcfs).events);
    let median = r.median();
    let events = run_policy(das2.clone(), Policy::Fcfs).events;
    println!(
        "  -> {:.2} M events/s",
        events as f64 / median.as_secs_f64() / 1e6
    );

    let w = das2.clone();
    b.case("sim/das2-100k/backfill", move || {
        run_policy(w.clone(), Policy::FcfsBackfill).events
    });
    let w = sp2.clone();
    b.case("sim/sp2-50k/backfill", move || {
        run_policy(w.clone(), Policy::FcfsBackfill).events
    });

    section("baseline (CQsim-like) for comparison");
    let w = das2.clone();
    b.case("baseline/das2-100k/fcfs", move || run_baseline(&w, Policy::Fcfs).events);

    section("workload generation");
    b.case("gen/das2-100k", || Das2Model::default().generate(100_000, 1).jobs.len());
    b.case("gen/sp2-50k", || SdscSp2Model::default().generate(50_000, 1).jobs.len());
}
