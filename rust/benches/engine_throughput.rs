//! Bench: core engine + simulator throughput (events/second) — the L3
//! hot-path numbers tracked in EXPERIMENTS.md §Perf — plus the
//! scheduling-round planning cost at deep queues (availability-timeline
//! refactor): incremental shared profile vs rebuild-per-round baseline.
//!
//! `--smoke` (or SMOKE=1) runs small sizes with one iteration so CI can
//! surface profile-regression perf breakage without multi-second runs.

use sst_sched::core::time::SimTime;
use sst_sched::baseline::run_baseline;
use sst_sched::job::{Job, WaitQueue};
use sst_sched::resources::{AvailabilityProfile, Cluster, ResourceVector};
use sst_sched::sched::{ArrivalOrder, ConservativeScheduler, Policy, RunningJob, SchedInput, Scheduler};
use sst_sched::sim::run_policy;
use sst_sched::trace::{Das2Model, SdscSp2Model};
use sst_sched::util::bench::{section, Bench};

/// Scheduling-round planning cost at a deep queue: `queued` waiting jobs
/// on a fully busy machine with `running` release points. Measures one
/// conservative-backfill round (the planning-heaviest policy: one slot
/// search + reservation per queued job).
///
/// `incremental` clones the maintained profile per round (what the
/// simulation core does now); the baseline re-sorts the raw release
/// vector and folds it into a fresh profile every round (what every
/// round paid before the refactor).
fn sched_round_cases(b: &mut Bench, queued: usize, running: usize) {
    let nodes = 512usize;
    let cores_per_node = 16u64;
    let mut cluster = Cluster::homogeneous(nodes, cores_per_node, 0);
    let total = cluster.total_cores();
    // Fill the machine completely so no candidate can start: rounds pay
    // pure planning cost, and the cluster needs no reset between runs.
    let mut running_jobs: Vec<RunningJob> = Vec::with_capacity(running);
    let cores_each = total / running as u64;
    for i in 0..running {
        let j = Job::simple(1_000_000 + i as u64, 0, cores_each.max(1), 10);
        if let Some(a) = cluster.allocate(&j, sst_sched::resources::AllocPolicy::FirstFit) {
            running_jobs.push(RunningJob {
                id: j.id,
                cores: a.cores(),
                est_end: SimTime(100 + (i as u64 % 97) * 50),
                start: SimTime(0),
                priority: 0,
            });
        }
    }
    // Mop up any remainder so free_cores == 0.
    while cluster.free_cores() > 0 {
        let j = Job::simple(2_000_000, 0, cluster.free_cores(), 10);
        let a = cluster.allocate(&j, sst_sched::resources::AllocPolicy::FirstFit).unwrap();
        running_jobs.push(RunningJob {
            id: j.id,
            cores: a.cores(),
            est_end: SimTime(5_000),
            start: SimTime(0),
            priority: 0,
        });
    }
    let mut queue = WaitQueue::new();
    for i in 0..queued {
        let i = i as u64;
        queue.push(Job::with_estimate(i, 0, 1 + (i % 64), 100 + i % 900, 100 + i % 900));
    }
    let releases: Vec<(u64, u64)> =
        running_jobs.iter().map(|r| (r.est_end.ticks(), r.cores)).collect();
    let maintained =
        AvailabilityProfile::from_releases(0, cluster.free_cores(), total, &releases);

    let label = format!("round/cons-{queued}q-{running}r/incremental");
    {
        let mut cluster = cluster.clone();
        let queue = &queue;
        let running_jobs = &running_jobs;
        let maintained = &maintained;
        b.case(&label, move || {
            // What a dispatch round costs now: clone the maintained
            // timeline, plan every queued job onto it.
            let input = SchedInput {
                now: SimTime(0),
                queue,
                running: running_jobs,
                profile: maintained,
                order: &ArrivalOrder,
            };
            ConservativeScheduler::new().schedule(&input, &mut cluster).len()
        });
    }
    let label = format!("round/cons-{queued}q-{running}r/rebuild-per-round");
    {
        let mut cluster = cluster.clone();
        let queue = &queue;
        let running_jobs = &running_jobs;
        let releases = &releases;
        b.case(&label, move || {
            // What a dispatch round cost before: gather + sort the raw
            // release vector and fold a fresh profile, then plan.
            let rebuilt = AvailabilityProfile::from_releases(
                0,
                cluster.free_cores(),
                total,
                releases,
            );
            let input = SchedInput {
                now: SimTime(0),
                queue,
                running: running_jobs,
                profile: &rebuilt,
                order: &ArrivalOrder,
            };
            ConservativeScheduler::new().schedule(&input, &mut cluster).len()
        });
    }
}

/// Memory-constrained scheduling round (multi-resource planning API),
/// plus the lazy-materialization pin: a memory-*tracking* profile over a
/// trace that carries no memory demands must never materialize its
/// memory timeline — the cores-only workload pays (near) zero for the
/// second dimension.
fn sched_round_mem_cases(b: &mut Bench, queued: usize) {
    let nodes = 512usize;
    let cores_per_node = 16u64;
    let mem_per_node = 4096u64;
    let cluster = Cluster::homogeneous(nodes, cores_per_node, mem_per_node);
    let total = ResourceVector::new(cluster.total_cores(), cluster.total_memory_mb());

    let queue_of = |mem: bool| {
        let mut q = WaitQueue::new();
        for i in 0..queued {
            let i = i as u64;
            let mut j = Job::with_estimate(i, 0, 1 + (i % 64), 100 + i % 900, 100 + i % 900);
            if mem {
                j.memory_mb = 256 + (i % 16) * 256;
            }
            q.push(j);
        }
        q
    };

    // Shared setup: the whole machine planned busy until t=500 (cores +
    // memory for the memory-carrying variant), so every slot lands in
    // the future — rounds pay pure planning cost and never mutate the
    // cluster between iterations.
    let profile_of = |mem: bool| {
        let mut p = AvailabilityProfile::new_v(
            0,
            ResourceVector::new(total.cores, total.memory_mb),
            total,
        );
        p.hold_v(
            0,
            500,
            ResourceVector::new(total.cores, if mem { total.memory_mb } else { 0 }),
        );
        p
    };

    // Lazy pin (asserted outside the timed loop): no memory demands ->
    // no memory timeline, even on a memory-tracking profile.
    assert!(
        !profile_of(false).has_memory_dimension(),
        "cores-only round must not materialize the memory dimension"
    );
    assert!(profile_of(true).has_memory_dimension());

    for (label, mem) in [("cores-only", false), ("memory", true)] {
        let mut cluster = cluster.clone();
        let queue = queue_of(mem);
        let profile = profile_of(mem);
        let label = format!("round/cons-{queued}q-mem/{label}");
        b.case(&label, move || {
            let input = SchedInput {
                now: SimTime(0),
                queue: &queue,
                running: &[],
                profile: &profile,
                order: &ArrivalOrder,
            };
            ConservativeScheduler::new().schedule(&input, &mut cluster).len()
        });
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("SMOKE").map(|v| v == "1").unwrap_or(false);
    let (das2_n, sp2_n, runs) = if smoke { (5_000, 3_000, 1) } else { (100_000, 50_000, 5) };

    section("event-driven simulator throughput");
    let das2 = Das2Model::default().generate(das2_n, 1).drop_infeasible();
    let sp2 = SdscSp2Model::default().generate(sp2_n, 1).drop_infeasible();
    let mut b = Bench::new(if smoke { 0 } else { 1 }, runs);

    let w = das2.clone();
    let r = b.case("sim/das2/fcfs", move || run_policy(w.clone(), Policy::Fcfs).events);
    let median = r.median();
    let events = run_policy(das2.clone(), Policy::Fcfs).events;
    println!(
        "  -> {:.2} M events/s",
        events as f64 / median.as_secs_f64() / 1e6
    );

    let w = das2.clone();
    b.case("sim/das2/backfill", move || {
        run_policy(w.clone(), Policy::FcfsBackfill).events
    });
    let w = das2.clone();
    b.case("sim/das2/cons-backfill", move || {
        run_policy(w.clone(), Policy::ConservativeBackfill).events
    });
    let w = sp2.clone();
    b.case("sim/sp2/backfill", move || {
        run_policy(w.clone(), Policy::FcfsBackfill).events
    });

    section("scheduling-round planning cost (availability profile)");
    if smoke {
        sched_round_cases(&mut b, 2_000, 200);
    } else {
        sched_round_cases(&mut b, 10_000, 1_000);
        sched_round_cases(&mut b, 10_000, 5_000);
    }

    section("memory-constrained round (lazy second dimension)");
    sched_round_mem_cases(&mut b, if smoke { 2_000 } else { 10_000 });

    section("baseline (CQsim-like) for comparison");
    let w = das2.clone();
    b.case("baseline/das2/fcfs", move || run_baseline(&w, Policy::Fcfs).events);

    section("workload generation");
    b.case("gen/das2", move || Das2Model::default().generate(das2_n, 1).jobs.len());
    b.case("gen/sp2", move || SdscSp2Model::default().generate(sp2_n, 1).jobs.len());
}
