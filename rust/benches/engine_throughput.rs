//! Bench: core engine + simulator throughput (events/second) — the L3
//! hot-path numbers tracked in EXPERIMENTS.md §Perf — plus the
//! scheduling-round planning cost at deep queues and the streamed
//! million-job ingestion case.
//!
//! The suite itself lives in `sst_sched::harness::bench_suite` so the
//! `sst-sched bench` subcommand can run the same cases and emit the
//! machine-readable `BENCH_engine.json` the CI perf trajectory consumes;
//! this binary stays the classic `cargo bench` entry point.
//!
//! `--smoke` (or SMOKE=1) runs small sizes with one iteration so CI can
//! surface perf breakage without multi-second runs.

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("SMOKE").map(|v| v == "1").unwrap_or(false);
    sst_sched::harness::bench_suite::engine_throughput_suite(smoke);
}
