"""L2 JAX model: batched scheduler scoring.

Computes everything the Rust coordinator's backfill / best-fit scheduler
needs for one scheduling event, over a padded queue of Q jobs against N
nodes, in a single fused HLO module:

  * ``waste[q]``       — min non-negative slack over nodes (L1 Pallas
                         kernel ``kernels.scores.fit_waste``), NOFIT if the
                         job fits on no *single* node.
  * ``backfill_ok[q]`` — 1.0 iff the job fits in the machine's total free
                         cores (multi-node spanning allowed) AND would not
                         delay the EASY reservation: either it finishes
                         within the shadow time or it uses only the extra
                         (non-reserved) cores.
  * ``priority[q]``    — aging-weighted rank used to order candidates:
                         ``aging*wait - waste_w*span_penalty``, where the
                         penalty is the single-node waste when one exists
                         and the flat SPAN_COST when the job must span
                         nodes; jobs that do not fit at all are pushed to
                         -NOFIT.

Shapes are static (AOT): the Rust side pads the live queue to Q and the
node-free vector to N. Padding convention: padded job slots carry req=0,
est=0, wait=-inf surrogate (the Rust side masks them out by index anyway);
padded node slots carry free=0 and can never increase any job's fit,
because a 0-core node only "fits" req=0 padding jobs.

This module is lowered ONCE by aot.py to artifacts/model.hlo.txt and
executed from Rust via PJRT; Python never runs on the simulation path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.scores import NOFIT, fit_waste

# Default AOT shapes; rust/src/runtime/mod.rs mirrors these constants.
Q_PAD = 256
N_PAD = 512

# Waste surrogate charged to jobs that must span nodes — mirrors
# rust/src/sched/scorer.rs SPAN_COST.
SPAN_COST = 128.0


def score_queue(job_req, job_est, job_wait, node_free, params):
    """Score a padded queue. See module docstring.

    Args:
      job_req:   f32[Q] requested cores per job.
      job_est:   f32[Q] user-estimated runtime (seconds).
      job_wait:  f32[Q] time spent waiting so far (seconds).
      node_free: f32[N] free cores per node.
      params:    f32[4] = [shadow_time, extra_cores, aging_weight,
                 waste_weight].

    Returns:
      (waste f32[Q], backfill_ok f32[Q], priority f32[Q]).
    """
    shadow_time = params[0]
    extra_cores = params[1]
    aging_weight = params[2]
    waste_weight = params[3]

    waste = fit_waste(job_req, node_free)  # L1 Pallas kernel
    single = waste < NOFIT * 0.5
    total_free = jnp.sum(node_free)
    fits_total = job_req <= total_free
    short_enough = job_est <= shadow_time
    small_enough = job_req <= extra_cores
    backfill_ok = jnp.logical_and(
        fits_total, jnp.logical_or(short_enough, small_enough)
    )
    span_penalty = jnp.where(single, waste, SPAN_COST)
    priority = (
        aging_weight * job_wait - waste_weight * span_penalty
        - jnp.where(fits_total, 0.0, NOFIT)
    )
    return waste, backfill_ok.astype(jnp.float32), priority


def lower_score_queue(q: int = Q_PAD, n: int = N_PAD):
    """jit + lower score_queue at the AOT shapes; returns the Lowered."""
    f32 = jnp.float32
    specs = (
        jax.ShapeDtypeStruct((q,), f32),  # job_req
        jax.ShapeDtypeStruct((q,), f32),  # job_est
        jax.ShapeDtypeStruct((q,), f32),  # job_wait
        jax.ShapeDtypeStruct((n,), f32),  # node_free
        jax.ShapeDtypeStruct((4,), f32),  # params
    )
    return jax.jit(score_queue).lower(*specs)
