"""AOT bridge: lower the L2 model to HLO *text* for the Rust runtime.

HLO text (NOT ``lowered.compile()`` / ``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids that
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids, so text round-trips cleanly — see
/opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts/model.hlo.txt

Python runs ONCE at build time; the Rust binary is self-contained after
``make artifacts``.
"""

from __future__ import annotations

import argparse
import os

from jax._src.lib import xla_client as xc

from .model import N_PAD, Q_PAD, lower_score_queue


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (return_tuple=True).

    return_tuple=True wraps the outputs in a tuple root so the Rust side
    always unpacks a tuple regardless of output arity.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt")
    ap.add_argument("--q", type=int, default=Q_PAD, help="padded queue length")
    ap.add_argument("--n", type=int, default=N_PAD, help="padded node count")
    args = ap.parse_args()

    lowered = lower_score_queue(args.q, args.n)
    text = to_hlo_text(lowered)
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(text)
    print(f"wrote {len(text)} chars to {args.out} (Q={args.q}, N={args.n})")


if __name__ == "__main__":
    main()
