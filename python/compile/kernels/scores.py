"""L1 Pallas kernel: tiled queue-x-node fit scoring.

This is the O(Q*N) inner loop of best-fit / backfill scheduling: for every
queued job q and every node n, compute the slack ``node_free[n] -
job_req[q]`` and reduce to the per-job minimum non-negative slack (the
"waste" of the best-fitting node). Jobs that fit nowhere get the ``NOFIT``
sentinel.

TPU mapping (see DESIGN.md SS Hardware-Adaptation): the fit matrix is tiled
(Q_TILE x N_TILE) = (8 x 128) to match the VPU lane shape; each tile's
operands live in VMEM (req column tile + free row tile, ~4.5 KiB combined),
and the row-min is accumulated across the N grid dimension, which Pallas
executes sequentially, so the output block acts as a running-min
accumulator. No MXU use -- the computation is elementwise + reduction.

Runs with ``interpret=True`` everywhere in this repo: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and interpret mode lowers the kernel to
plain HLO so the Rust runtime can run it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sentinel for "this job fits on no node". Kept far below f32 max so
# arithmetic on it (priority mixing in the L2 model) stays finite.
NOFIT = 1.0e9

# VPU-aligned tile shape: 8 sublanes x 128 lanes.
Q_TILE = 8
N_TILE = 128


def _fit_kernel(req_ref, free_ref, waste_ref):
    """One (Q_TILE, N_TILE) tile of the fit matrix, min-reduced over N.

    Grid is (Q/Q_TILE, N/N_TILE); the N axis is the innermost (sequential)
    grid dimension, so ``waste_ref`` — whose index_map ignores the N grid
    coordinate — persists across N steps and accumulates the running min.
    """
    n_idx = pl.program_id(1)
    req = req_ref[...]  # (Q_TILE, 1)
    free = free_ref[...]  # (1, N_TILE)
    slack = free - req  # (Q_TILE, N_TILE) broadcast
    slack = jnp.where(slack >= 0.0, slack, NOFIT)
    tile_min = jnp.min(slack, axis=1, keepdims=True)  # (Q_TILE, 1)

    @pl.when(n_idx == 0)
    def _init():
        waste_ref[...] = tile_min

    @pl.when(n_idx != 0)
    def _acc():
        waste_ref[...] = jnp.minimum(waste_ref[...], tile_min)


@functools.partial(jax.jit, static_argnames=())
def fit_waste(job_req: jax.Array, node_free: jax.Array) -> jax.Array:
    """Per-job minimum non-negative slack over all nodes.

    Args:
      job_req: f32[Q] requested cores per queued job (padded slots may be 0).
      node_free: f32[N] free cores per node (padded slots may be 0).

    Returns:
      f32[Q]: ``min_n (node_free[n] - job_req[q])`` over nodes where the
      job fits, else ``NOFIT``.

    Q must be a multiple of Q_TILE and N a multiple of N_TILE; the Rust
    caller pads to the AOT shapes (see aot.py).
    """
    q = job_req.shape[0]
    n = node_free.shape[0]
    if q % Q_TILE != 0 or n % N_TILE != 0:
        raise ValueError(f"shapes must be tile-aligned, got Q={q} N={n}")
    req2 = job_req.astype(jnp.float32).reshape(q, 1)
    free2 = node_free.astype(jnp.float32).reshape(1, n)
    out = pl.pallas_call(
        _fit_kernel,
        grid=(q // Q_TILE, n // N_TILE),
        in_specs=[
            pl.BlockSpec((Q_TILE, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, N_TILE), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((Q_TILE, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((q, 1), jnp.float32),
        interpret=True,
    )(req2, free2)
    return out.reshape(q)
