"""Pure-jnp oracle for the L1 fit-scoring kernel and the L2 model.

This file is the CORE correctness signal: pytest asserts the Pallas kernel
(scores.fit_waste) and the full L2 model (model.score_queue) match these
reference implementations bit-for-allclose. Keep it boring: no Pallas, no
tiling, one obvious jnp expression per quantity.
"""

from __future__ import annotations

import jax.numpy as jnp

from .scores import NOFIT

# Mirrors model.SPAN_COST (defined here too so ref.py stays import-light).
SPAN_COST = 128.0


def fit_waste_ref(job_req: jnp.ndarray, node_free: jnp.ndarray) -> jnp.ndarray:
    """min over nodes of (free - req) where >= 0, else NOFIT. f32[Q]."""
    req = job_req.astype(jnp.float32)[:, None]  # (Q, 1)
    free = node_free.astype(jnp.float32)[None, :]  # (1, N)
    slack = free - req
    slack = jnp.where(slack >= 0.0, slack, NOFIT)
    return jnp.min(slack, axis=1)


def score_queue_ref(job_req, job_est, job_wait, node_free, params):
    """Reference for model.score_queue. See model.py for semantics.

    params: f32[4] = [shadow_time, extra_cores, aging_weight, waste_weight]
    Returns (waste, backfill_ok, priority), each f32[Q].
    """
    shadow_time, extra_cores, aging_weight, waste_weight = (
        params[0],
        params[1],
        params[2],
        params[3],
    )
    waste = fit_waste_ref(job_req, node_free)
    single = waste < NOFIT * 0.5
    fits_total = job_req.astype(jnp.float32) <= jnp.sum(
        node_free.astype(jnp.float32)
    )
    short_enough = job_est.astype(jnp.float32) <= shadow_time
    small_enough = job_req.astype(jnp.float32) <= extra_cores
    backfill_ok = jnp.logical_and(
        fits_total, jnp.logical_or(short_enough, small_enough)
    )
    span_penalty = jnp.where(single, waste, SPAN_COST)
    priority = (
        aging_weight * job_wait.astype(jnp.float32)
        - waste_weight * span_penalty
        - jnp.where(fits_total, 0.0, NOFIT)
    )
    return waste, backfill_ok.astype(jnp.float32), priority
