"""pytest: Pallas kernel vs pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps tile-aligned shapes and adversarial value distributions;
every property asserts allclose against kernels.ref.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.ref import fit_waste_ref
from compile.kernels.scores import N_TILE, NOFIT, Q_TILE, fit_waste

RNG = np.random.default_rng(0)


def _rand(q, n, req_max=64.0, free_max=64.0, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    req = rng.uniform(0.0, req_max, size=q).astype(np.float32)
    free = rng.uniform(0.0, free_max, size=n).astype(np.float32)
    return jnp.asarray(req), jnp.asarray(free)


def _check(req, free):
    got = np.asarray(fit_waste(req, free))
    want = np.asarray(fit_waste_ref(req, free))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


class TestFitWasteBasic:
    def test_single_tile(self):
        req, free = _rand(Q_TILE, N_TILE, seed=1)
        _check(req, free)

    def test_default_shapes(self):
        req, free = _rand(256, 512, seed=2)
        _check(req, free)

    def test_exact_fit_has_zero_waste(self):
        req = jnp.zeros((Q_TILE,), jnp.float32).at[0].set(16.0)
        free = jnp.zeros((N_TILE,), jnp.float32).at[3].set(16.0)
        got = np.asarray(fit_waste(req, free))
        assert got[0] == 0.0

    def test_no_fit_is_nofit(self):
        req = jnp.full((Q_TILE,), 100.0, jnp.float32)
        free = jnp.full((N_TILE,), 1.0, jnp.float32)
        got = np.asarray(fit_waste(req, free))
        np.testing.assert_allclose(got, NOFIT)

    def test_zero_req_matches_min_free(self):
        req = jnp.zeros((Q_TILE,), jnp.float32)
        _, free = _rand(Q_TILE, N_TILE, seed=3)
        got = np.asarray(fit_waste(req, free))
        np.testing.assert_allclose(got, float(np.min(np.asarray(free))), rtol=1e-6)

    def test_misaligned_shapes_rejected(self):
        with pytest.raises(ValueError):
            fit_waste(jnp.zeros((7,), jnp.float32), jnp.zeros((N_TILE,), jnp.float32))
        with pytest.raises(ValueError):
            fit_waste(jnp.zeros((Q_TILE,), jnp.float32), jnp.zeros((100,), jnp.float32))


class TestFitWasteProperties:
    @settings(max_examples=25, deadline=None)
    @given(
        qt=st.integers(min_value=1, max_value=8),
        nt=st.integers(min_value=1, max_value=4),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_matches_ref_random_shapes(self, qt, nt, seed):
        req, free = _rand(qt * Q_TILE, nt * N_TILE, seed=seed)
        _check(req, free)

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        req_max=st.sampled_from([0.5, 8.0, 128.0, 4096.0]),
        free_max=st.sampled_from([0.5, 8.0, 128.0, 4096.0]),
    )
    def test_matches_ref_value_ranges(self, seed, req_max, free_max):
        req, free = _rand(2 * Q_TILE, 2 * N_TILE, req_max, free_max, seed=seed)
        _check(req, free)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_integer_valued_inputs(self, seed):
        # Core counts are integers in the simulator; exercise exact ties.
        rng = np.random.default_rng(seed)
        req = jnp.asarray(rng.integers(0, 32, size=2 * Q_TILE).astype(np.float32))
        free = jnp.asarray(rng.integers(0, 32, size=N_TILE).astype(np.float32))
        _check(req, free)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_padding_nodes_never_help(self, seed):
        # Appending free=0 node padding must not change any positive-req job.
        req, free = _rand(Q_TILE, N_TILE, seed=seed)
        req = req + 0.001  # strictly positive
        padded = jnp.concatenate([free, jnp.zeros((N_TILE,), jnp.float32)])
        a = np.asarray(fit_waste(req, free))
        b = np.asarray(fit_waste(req, padded))
        np.testing.assert_allclose(a, b, rtol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_monotone_in_free(self, seed):
        # Adding one generous node can only decrease (or keep) waste.
        req, free = _rand(Q_TILE, N_TILE, seed=seed)
        richer = free.at[0].set(1e6)
        a = np.asarray(fit_waste(req, free))
        b = np.asarray(fit_waste(req, richer))
        assert (b <= a + 1e-6).all()

    def test_deterministic(self):
        req, free = _rand(2 * Q_TILE, N_TILE, seed=7)
        a = np.asarray(fit_waste(req, free))
        b = np.asarray(fit_waste(req, free))
        np.testing.assert_array_equal(a, b)
