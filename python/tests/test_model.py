"""pytest: L2 model (score_queue) vs oracle + AOT lowering checks."""

from __future__ import annotations

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.aot import to_hlo_text
from compile.kernels.ref import score_queue_ref
from compile.kernels.scores import NOFIT
from compile.model import N_PAD, Q_PAD, lower_score_queue, score_queue


def _inputs(q=64, n=128, seed=0):
    rng = np.random.default_rng(seed)
    req = jnp.asarray(rng.integers(0, 64, size=q).astype(np.float32))
    est = jnp.asarray(rng.uniform(10.0, 7200.0, size=q).astype(np.float32))
    wait = jnp.asarray(rng.uniform(0.0, 3600.0, size=q).astype(np.float32))
    free = jnp.asarray(rng.integers(0, 64, size=n).astype(np.float32))
    params = jnp.asarray(
        [rng.uniform(0, 7200), rng.integers(0, 256), 1.0, 0.5], dtype=jnp.float32
    )
    return req, est, wait, free, params


def _check(args):
    got = score_queue(*args)
    want = score_queue_ref(*args)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-5)


class TestScoreQueue:
    def test_matches_ref_default(self):
        _check(_inputs())

    def test_matches_ref_aot_shapes(self):
        _check(_inputs(q=Q_PAD, n=N_PAD, seed=3))

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_matches_ref_random(self, seed):
        _check(_inputs(seed=seed))

    def test_backfill_semantics(self):
        # One 4-core job, est below shadow: backfillable. One 2000-core job
        # that exceeds total free cores (128*8=1024): not backfillable,
        # priority driven to -NOFIT.
        req = jnp.zeros((8,), jnp.float32).at[0].set(4.0).at[1].set(2000.0)
        est = jnp.full((8,), 50.0, jnp.float32)
        wait = jnp.zeros((8,), jnp.float32)
        free = jnp.full((128,), 8.0, jnp.float32)
        params = jnp.asarray([100.0, 0.0, 1.0, 0.5], dtype=jnp.float32)
        waste, ok, prio = score_queue(req, est, wait, free, params)
        assert float(ok[0]) == 1.0
        assert float(ok[1]) == 0.0
        assert float(waste[1]) == NOFIT
        assert float(prio[1]) <= -NOFIT * 0.5

    def test_small_enough_backfills_past_shadow(self):
        # est > shadow but req <= extra_cores: still backfillable (EASY).
        req = jnp.zeros((8,), jnp.float32).at[0].set(2.0)
        est = jnp.full((8,), 1e6, jnp.float32)
        wait = jnp.zeros((8,), jnp.float32)
        free = jnp.full((128,), 8.0, jnp.float32)
        params = jnp.asarray([10.0, 4.0, 1.0, 0.5], dtype=jnp.float32)
        _, ok, _ = score_queue(req, est, wait, free, params)
        assert float(ok[0]) == 1.0

    def test_aging_orders_priority(self):
        # Same req/est, different wait: longer wait -> higher priority.
        req = jnp.full((8,), 4.0, jnp.float32)
        est = jnp.full((8,), 50.0, jnp.float32)
        wait = jnp.arange(8, dtype=jnp.float32) * 100.0
        free = jnp.full((128,), 8.0, jnp.float32)
        params = jnp.asarray([100.0, 8.0, 1.0, 0.5], dtype=jnp.float32)
        _, _, prio = score_queue(req, est, wait, free, params)
        p = np.asarray(prio)
        assert (np.diff(p) > 0).all()


class TestAot:
    def test_lowering_produces_hlo_text(self):
        text = to_hlo_text(lower_score_queue(32, 128))
        assert "ENTRY" in text
        assert "f32[32]" in text
        assert "f32[128]" in text

    def test_default_shapes_lower(self):
        text = to_hlo_text(lower_score_queue())
        assert f"f32[{Q_PAD}]" in text
        assert f"f32[{N_PAD}]" in text
