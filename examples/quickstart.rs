//! Quickstart: simulate a small DAS-2-like workload under EASY
//! backfilling and print the scheduling report.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use sst_sched::sched::Policy;
use sst_sched::sim::Simulation;
use sst_sched::trace::Das2Model;

fn main() {
    // 1. Generate a workload: 5,000 grid-like jobs for a 72-node
    //    dual-core cluster, arrivals compressed so a queue actually forms.
    let workload = Das2Model::default()
        .generate(5_000, 42)
        .scale_arrivals(0.5)
        .drop_infeasible();
    println!(
        "workload: {} jobs, offered load {:.2}",
        workload.jobs.len(),
        workload.offered_load()
    );

    // 2. Run the event-driven simulation under EASY backfilling.
    let report = Simulation::new(workload, Policy::FcfsBackfill).with_seed(1).run(None);

    // 3. Inspect the results.
    let stats = report.wait_stats();
    println!("completed        {}", stats.jobs);
    println!("DES events       {}", report.events);
    println!("sim end          {} s", report.end_time.ticks());
    println!("mean wait        {:.1} s", stats.mean_wait);
    println!("p95 wait         {:.1} s", stats.p95_wait);
    println!("mean slowdown    {:.2}", stats.mean_slowdown);
    println!("mean utilization {:.3}", report.mean_utilization);

    // 4. Occupancy over time (Fig 3(a)-style series, 12 samples).
    println!("\nnode occupancy over time:");
    for (t, occ) in report.occupancy.downsample(12) {
        println!("  t={:>9}  {:>5.1} nodes  {}", t.ticks(), occ, "#".repeat(occ as usize / 2));
    }
}
