//! Fault tolerance: utilization recovery under node failures, with and
//! without preemptive (checkpoint/restart) backfilling.
//!
//! Every case runs against the *same* seeded failure trace (the
//! injector's RNG stream is private and policy-independent), so the
//! comparison isolates the scheduling + preemption policy:
//!
//! * `fcfs / none` — blocking discipline; failure victims start over.
//! * `fcfs / checkpoint` — failure victims resume from checkpoint.
//! * `fcfs-backfill / none` — EASY backfilling around blocked heads.
//! * `fcfs-backfill / checkpoint` — backfilling + checkpoint/restart:
//!   the fault-tolerant configuration the tentpole promises.
//!
//! ```bash
//! cargo run --release --example fault_tolerance
//! ```

use sst_sched::core::time::SimDuration;
use sst_sched::harness::{fault_comparison, print_fault_rows, FaultCompareOpts};
use sst_sched::job::Job;
use sst_sched::sched::{Policy, PreemptionConfig, PreemptionMode};
use sst_sched::sim::FaultConfig;
use sst_sched::trace::Workload;

/// A deliberately backfill-hostile-for-FCFS workload: pairs of wide jobs
/// that block the queue head, with streams of small short jobs behind
/// them that could run in the leftover cores.
fn workload() -> Workload {
    let mut jobs = Vec::new();
    let mut id = 0u64;
    let mut push = |id: &mut u64, submit: u64, cores: u64, runtime: u64| {
        *id += 1;
        jobs.push(Job::with_estimate(*id, submit, cores, runtime, runtime));
    };
    for epoch in 0..10u64 {
        let t0 = epoch * 3_600;
        push(&mut id, t0, 48, 3_000); // wide A
        push(&mut id, t0 + 2, 48, 3_000); // wide B — blocks the head
        for i in 0..30u64 {
            push(&mut id, t0 + 5 + i, 4, 300); // backfill fodder
        }
    }
    // 16 nodes x 4 cores = 64 cores.
    Workload::new("ft-demo", jobs, 16, 4)
}

fn main() {
    let faults = FaultConfig { mtbf: 6_000.0, mttr: 1_500.0, seed: 2026, ..FaultConfig::default() };
    let ckpt = PreemptionConfig {
        mode: PreemptionMode::Checkpoint,
        checkpoint_overhead: SimDuration(60),
        restart_overhead: SimDuration(60),
        starvation_threshold: SimDuration(0),
    };
    let none = PreemptionConfig::default();
    let w = workload();
    println!(
        "workload: {} jobs on 16 nodes x 4 cores; failure trace mtbf={}s mttr={}s seed={}\n",
        w.jobs.len(),
        faults.mtbf,
        faults.mttr,
        faults.seed
    );
    let cases = [
        (Policy::Fcfs, none),
        (Policy::Fcfs, ckpt),
        (Policy::FcfsBackfill, none),
        (Policy::FcfsBackfill, ckpt),
    ];
    let rows =
        fault_comparison(&w, &FaultCompareOpts { faults, ..FaultCompareOpts::default() }, &cases);
    print_fault_rows(&rows);

    let fcfs = &rows[0];
    let ft = &rows[3]; // backfill + checkpoint
    assert!(fcfs.failures > 0, "trace injected no failures — vacuous demo");
    assert_eq!(fcfs.failures, ft.failures, "cases must share one failure trace");
    println!(
        "effective utilization: fcfs/none {:.3} -> backfill/checkpoint {:.3}",
        fcfs.effective_utilization, ft.effective_utilization
    );
    println!(
        "lost work:             fcfs/none {:.0} core-s -> backfill/checkpoint {:.0} core-s",
        fcfs.lost_work, ft.lost_work
    );
    println!(
        "makespan:              fcfs/none {} s -> backfill/checkpoint {} s",
        fcfs.makespan, ft.makespan
    );
    // The tentpole's acceptance claim: under the same failure trace,
    // preemptive (checkpoint/restart) backfill achieves strictly higher
    // effective utilization than non-preemptive FCFS.
    assert!(
        ft.effective_utilization > fcfs.effective_utilization,
        "expected backfill+checkpoint ({:.4}) to beat FCFS ({:.4})",
        ft.effective_utilization,
        fcfs.effective_utilization
    );
    // Checkpointing eliminates redone work entirely.
    assert!(ft.lost_work <= fcfs.lost_work);
    println!("\nOK: preemptive backfill strictly improves effective utilization under failures.");
}
