//! End-to-end driver: exercises the FULL system on a real small workload,
//! proving all layers compose (EXPERIMENTS.md §End-to-end):
//!
//! 1. L2/L1 artifact — loads the AOT-compiled JAX+Pallas queue-scoring
//!    model (`artifacts/model.hlo.txt`) on the PJRT CPU client;
//! 2. L3 — simulates 20k DAS-2-like jobs under EASY backfilling with the
//!    XLA scorer on the scheduling hot path;
//! 3. validates the run against the independent CQsim-like baseline;
//! 4. asserts XLA-scored decisions match native-scored decisions;
//! 5. runs the Galactic Plane workflow and a modeled parallel scaling
//!    sweep — the paper's full result surface in one binary.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use sst_sched::baseline::run_baseline;
use sst_sched::metrics::{correlation, resample};
use sst_sched::parallel::run_jobs_parallel_modeled;
use sst_sched::runtime::{backfill_with_accel, Accel};
use sst_sched::sched::Policy;
use sst_sched::sim::{SimReport, Simulation};
use sst_sched::trace::Das2Model;
use sst_sched::workflow::generators::galactic_plane;
use sst_sched::workflow::WorkflowExecutor;

fn run_with(accel: Accel, workload: sst_sched::trace::Workload) -> SimReport {
    // Falls back to the native scorer when this build has no XLA/PJRT
    // support (`xla` cargo feature) or the artifact is missing.
    let sched = backfill_with_accel(accel).unwrap_or_else(|e| {
        eprintln!("note: {e:#}; falling back to --accel native");
        backfill_with_accel(Accel::Native).unwrap()
    });
    Simulation::new(workload, Policy::FcfsBackfill)
        .with_scheduler(Box::new(sched))
        .run(None)
}

fn main() {
    println!("=== sst-sched end-to-end driver ===\n");
    let workload = Das2Model::default()
        .generate(20_000, 2026)
        .scale_arrivals(0.5)
        .drop_infeasible();
    println!(
        "[1] workload: {} jobs, 72 nodes x 2 cores, offered load {:.2}",
        workload.jobs.len(),
        workload.offered_load()
    );

    // --- L1/L2/L3 composition: XLA-scored backfilling ---
    let t0 = std::time::Instant::now();
    let xla = run_with(Accel::Xla, workload.clone());
    let xla_wall = t0.elapsed();
    let s = xla.wait_stats();
    println!("\n[2] XLA-scored EASY backfilling (Pallas fit-kernel on the hot path):");
    println!("    completed {}   mean wait {:.1} s   p95 {:.1} s   util {:.3}",
        s.jobs, s.mean_wait, s.p95_wait, xla.mean_utilization);
    println!("    {} events in {:.0} ms ({:.0} ev/s)",
        xla.events, xla_wall.as_secs_f64() * 1e3,
        xla.events as f64 / xla_wall.as_secs_f64());

    // --- XLA vs native decision parity ---
    let native = run_with(Accel::Native, workload.clone());
    let starts = |r: &SimReport| {
        let mut v: Vec<(u64, u64)> =
            r.completed.iter().map(|j| (j.id, j.start.unwrap().ticks())).collect();
        v.sort_unstable();
        v
    };
    assert_eq!(starts(&xla), starts(&native), "XLA scorer changed scheduling decisions!");
    println!("\n[3] parity: XLA-scored and native-scored runs made IDENTICAL decisions");

    // --- validation vs the independent baseline ---
    let base = run_baseline(&workload, Policy::FcfsBackfill);
    let t1 = xla.end_time.max(base.end_time);
    let ours = resample(&xla.occupancy, sst_sched::core::time::SimTime::ZERO, t1, 48);
    let theirs = resample(&base.occupancy, sst_sched::core::time::SimTime::ZERO, t1, 48);
    let corr = correlation(&ours, &theirs);
    let bs = base.wait_stats();
    println!("\n[4] validation vs independent CQsim-like baseline:");
    println!("    occupancy correlation {corr:.4}");
    println!("    mean wait: ours {:.1} s vs baseline {:.1} s", s.mean_wait, bs.mean_wait);
    assert!(corr > 0.85, "validation failed: occupancy diverged (corr {corr})");

    // --- workflow component ---
    let wf = galactic_plane(17, 7, false);
    let tasks = wf.len();
    let crit = wf.critical_path_time();
    let rep = WorkflowExecutor::new(64, u64::MAX).run(wf);
    println!("\n[5] Galactic Plane workflow: {} tasks on 64 cpus", tasks);
    println!("    makespan {} s (critical path {:.0} s), mean task wait {:.1} s",
        rep.makespan.ticks(), crit, rep.mean_wait());

    // --- parallel scaling (modeled; single-CPU container) ---
    println!("\n[6] modeled conservative-PDES scaling (100k-job DAS-2-like):");
    let big = Das2Model::default().generate(100_000, 3).drop_infeasible();
    let mut base_ms = None;
    for ranks in [1usize, 2, 4, 8] {
        let rep = run_jobs_parallel_modeled(&big, Policy::FcfsBackfill, ranks, 86_400);
        let ms = rep.wall.as_secs_f64() * 1e3;
        let b = *base_ms.get_or_insert(ms);
        println!("    ranks {ranks}: modeled wall {ms:>8.1} ms   speedup {:.2}x", b / ms);
    }

    println!("\n=== all layers composed; end-to-end run OK ===");
}
