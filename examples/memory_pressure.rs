//! Memory pressure: why the planning layer needs a memory dimension.
//!
//! A workload where *cores* fit but *memory* doesn't. The cores-only
//! planner sees free cores at `now`, puts the blocked head's shadow at
//! `now`, and spends rounds re-proposing starts the resource manager
//! then refuses (refusal-retry churn); the memory-aware planner knows
//! when memory actually frees, plans the head's reservation there, and
//! backfills low-memory work into the gap.
//!
//! Run: cargo run --release --example memory_pressure

use sst_sched::job::Job;
use sst_sched::sched::Policy;
use sst_sched::sim::{SimReport, Simulation};
use sst_sched::trace::Workload;

/// One node, 8 cores, 1000 MB.
///
/// * j1: 4 cores, 800 MB, 100 s — starts at t=0.
/// * j2: 4 cores, 800 MB, 100 s — cores fit behind j1, memory doesn't:
///   blocked until j1 releases its 800 MB at t=100.
/// * j3: 4 cores, 100 MB, 200 s — fits next to j1 *and* next to j2.
fn workload() -> Workload {
    let jobs = vec![
        Job::with_memory(1, 0, 4, 800, 100),
        Job::with_memory(2, 1, 4, 800, 100),
        Job::with_memory(3, 2, 4, 100, 200),
    ];
    Workload::new("memory-pressure", jobs, 1, 8)
}

fn simulate(memory_aware: bool) -> SimReport {
    Simulation::new(workload(), Policy::FcfsBackfill)
        .with_mem_per_node(1000)
        .with_memory_aware(memory_aware)
        .run(None)
}

fn start(r: &SimReport, id: u64) -> u64 {
    r.completed.iter().find(|j| j.id == id).unwrap().start.unwrap().ticks()
}

fn main() {
    let cores_only = simulate(false);
    let mem_aware = simulate(true);

    for (name, r) in [("cores-only", &cores_only), ("memory-aware", &mem_aware)] {
        println!(
            "{name:13} starts: j1={} j2={} j3={}  mean wait {:.1}s  dispatch rounds {}",
            start(r, 1),
            start(r, 2),
            start(r, 3),
            r.wait_stats().mean_wait,
            r.dispatches,
        );
    }

    // Both planners complete everything, and the exact per-node
    // accounting (u64 free-memory pools + release invariants) means
    // node memory can never go negative — what differs is decision
    // quality, not safety.
    assert_eq!(cores_only.completed.len(), 3);
    assert_eq!(mem_aware.completed.len(), 3);
    for r in [&cores_only, &mem_aware] {
        for &(_, u) in r.memory_utilization.points() {
            assert!((0.0..=1.0).contains(&u), "memory utilization out of range: {u}");
        }
    }
    assert!(
        mem_aware.mean_memory_utilization > 0.0,
        "memory-aware run must record the memory series"
    );

    // The head j2 cannot start before t=100 either way (the resource
    // manager refuses the memory oversubscription)...
    assert_eq!(start(&cores_only, 2), 100);
    assert_eq!(start(&mem_aware, 2), 100);
    // ...but the cores-only planner placed j2's shadow at `now` (cores
    // were free!), so backfill had zero extra budget and j3 waited out
    // the whole backlog; the memory-aware shadow is t=100, which frees
    // j3 to backfill immediately.
    assert_eq!(start(&mem_aware, 3), 2, "memory-aware planner backfills j3 on arrival");
    assert!(
        start(&cores_only, 3) > start(&mem_aware, 3),
        "cores-only planner strands the backfill candidate"
    );
    // Wait-time verdict: memory awareness strictly wins on this tape.
    assert!(
        mem_aware.wait_stats().mean_wait < cores_only.wait_stats().mean_wait,
        "memory-aware must beat cores-only refusal-retry churn: {} !< {}",
        mem_aware.wait_stats().mean_wait,
        cores_only.wait_stats().mean_wait,
    );

    println!("\nmemory-aware planning cuts mean wait {:.1}s -> {:.1}s on the pressure tape",
        cores_only.wait_stats().mean_wait,
        mem_aware.wait_stats().mean_wait,
    );
}
