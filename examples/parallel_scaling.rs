//! Conservative-parallel scaling demo (paper Figs 5-6).
//!
//! Runs the same workloads through the threaded runner (correctness; this
//! container exposes one CPU, so threads cannot speed anything up) and
//! through the modeled runner (per-rank window times measured serially,
//! wall = conservative-window critical path) and prints both.
//!
//! ```bash
//! cargo run --release --example parallel_scaling
//! ```

use sst_sched::parallel::{
    run_jobs_parallel, run_jobs_parallel_modeled, run_workflow_parallel_modeled,
};
use sst_sched::sched::Policy;
use sst_sched::trace::Das2Model;
use sst_sched::util::table::Table;
use sst_sched::workflow::generators::galactic_plane_wide;

fn main() {
    let w = Das2Model::default().generate(100_000, 1).drop_infeasible();
    println!("job workload: {} jobs (DAS-2-like)\n", w.jobs.len());

    println!("threaded runner (correctness; 1-CPU container => no speedup expected):");
    let mut t = Table::new(&["ranks", "wall (ms)", "completed", "windows"]);
    for ranks in [1usize, 2, 4] {
        let rep = run_jobs_parallel(&w, Policy::FcfsBackfill, ranks, 86_400);
        t.row(&[
            ranks.to_string(),
            format!("{:.1}", rep.wall.as_secs_f64() * 1e3),
            rep.total_completed().to_string(),
            rep.windows.to_string(),
        ]);
    }
    t.print();

    println!("\nmodeled conservative-PDES wall time (per-rank window critical path):");
    let mut t = Table::new(&["ranks", "modeled wall (ms)", "speedup", "windows"]);
    let mut base = None;
    for ranks in [1usize, 2, 4, 8, 16] {
        let rep = run_jobs_parallel_modeled(&w, Policy::FcfsBackfill, ranks, 86_400);
        let ms = rep.wall.as_secs_f64() * 1e3;
        let b = *base.get_or_insert(ms);
        t.row(&[
            ranks.to_string(),
            format!("{ms:.1}"),
            format!("{:.2}x", b / ms),
            rep.windows.to_string(),
        ]);
    }
    t.print();

    let wf = galactic_plane_wide(17, 256, 1, false);
    println!("\nworkflow: galactic plane, {} tasks, cross-rank dependency traffic:", wf.len());
    let mut t = Table::new(&["ranks", "modeled wall (ms)", "speedup", "makespan (s)"]);
    let mut base = None;
    for ranks in [1usize, 2, 4, 8] {
        let rep = run_workflow_parallel_modeled(&wf, ranks, 256, 5);
        let ms = rep.wall.as_secs_f64() * 1e3;
        let b = *base.get_or_insert(ms);
        t.row(&[
            ranks.to_string(),
            format!("{ms:.2}"),
            format!("{:.2}x", b / ms),
            rep.end_time().to_string(),
        ]);
    }
    t.print();
}
