//! Workflow management demo (paper §3): load the paper's Listing-2 JSON
//! spec, execute it, then run the Pegasus-gallery workflows
//! (Montage/Galactic-Plane, SIPHT, Epigenomics 4/5/6seq, CyberShake,
//! LIGO) through the same engine and report makespans vs critical paths.
//!
//! ```bash
//! cargo run --release --example workflow_pipeline
//! ```

use sst_sched::util::table::{f, Table};
use sst_sched::workflow::generators as wfgen;
use sst_sched::workflow::{Workflow, WorkflowExecutor, WorkflowSpec};

fn run(name: &str, wf: Workflow, cpu: u64, table: &mut Table) {
    let tasks = wf.len();
    let crit = wf.critical_path_time();
    let work = wf.total_work();
    let rep = WorkflowExecutor::new(cpu, u64::MAX).run(wf);
    table.row(&[
        name.to_string(),
        tasks.to_string(),
        cpu.to_string(),
        rep.makespan.ticks().to_string(),
        f(crit),
        format!("{:.2}", work / rep.makespan.ticks().max(1) as f64),
        f(rep.mean_wait()),
        rep.peak_cpu.to_string(),
    ]);
}

fn main() {
    // 1. The paper's Listing-2 example, from its JSON input format.
    let spec = WorkflowSpec::load("examples/workflows/listing2.json")
        .expect("run from the repo root: examples/workflows/listing2.json");
    println!(
        "Listing 2: {} tasks on cpu={} mem={} MB, policy {:?}, preemption {}",
        spec.workflow.len(),
        spec.cpu_available,
        spec.memory_available_mb,
        spec.scheduling_policy,
        spec.preemption
    );
    let rep = WorkflowExecutor::new(spec.cpu_available, spec.memory_available_mb)
        .run(spec.workflow.clone());
    println!(
        "  makespan {} s (critical path {:.0} s), mean wait {:.1} s\n",
        rep.makespan.ticks(),
        spec.workflow.critical_path_time(),
        rep.mean_wait()
    );
    for t in &rep.tasks {
        println!(
            "  task {}: ready@{} start@{} end@{}",
            t.id,
            t.ready.ticks(),
            t.start.ticks(),
            t.end.ticks()
        );
    }

    // 2. The Pegasus gallery (paper §4 workloads + the rest of the Juve
    //    et al. profile set).
    println!("\nPegasus-gallery workflows (32-cpu pool):");
    let mut t = Table::new(&[
        "workflow",
        "tasks",
        "cpu",
        "makespan (s)",
        "crit path (s)",
        "speedup",
        "mean wait (s)",
        "peak cpu",
    ]);
    run("montage-64", wfgen::montage(64, 1, false), 32, &mut t);
    run("galactic-plane-17", wfgen::galactic_plane(17, 1, false), 32, &mut t);
    run("sipht-4", wfgen::sipht(4, 1, false), 32, &mut t);
    run("epigenomics-4seq", wfgen::epigenomics(4, 8, 1, false), 32, &mut t);
    run("epigenomics-5seq", wfgen::epigenomics(5, 8, 1, false), 32, &mut t);
    run("epigenomics-6seq", wfgen::epigenomics(6, 8, 1, false), 32, &mut t);
    run("cybershake-20", wfgen::cybershake(20, 1, false), 32, &mut t);
    run("ligo-30", wfgen::ligo_inspiral(30, 1, false), 32, &mut t);
    t.print();
}
