//! Compare the five scheduling algorithms (paper Fig 4(b)) on both
//! workload models and on a hand-built adversarial queue that makes the
//! policy differences vivid.
//!
//! ```bash
//! cargo run --release --example algorithm_comparison
//! ```

use sst_sched::job::Job;
use sst_sched::sched::Policy;
use sst_sched::sim::run_policy;
use sst_sched::trace::{Das2Model, SdscSp2Model, Workload};
use sst_sched::util::table::{f, Table};

fn compare(name: &str, make: impl Fn() -> Workload) {
    println!("== {name} ==");
    let mut t = Table::new(&["policy", "mean wait (s)", "p95 (s)", "slowdown", "util"]);
    for p in Policy::ALL {
        let r = run_policy(make(), p);
        let s = r.wait_stats();
        t.row(&[
            p.to_string(),
            f(s.mean_wait),
            f(s.p95_wait),
            f(s.mean_slowdown),
            format!("{:.3}", r.mean_utilization),
        ]);
    }
    t.print();
    println!();
}

fn main() {
    // Grid-style workload (small short jobs, DAS-2-like).
    compare("DAS-2-like, 6k jobs, compressed arrivals", || {
        Das2Model::default().generate(6_000, 7).scale_arrivals(0.45).drop_infeasible()
    });

    // Capability-HPC workload (large long jobs, SDSC-SP2-like).
    compare("SDSC-SP2-like, 3k jobs", || {
        SdscSp2Model::default().generate(3_000, 7).drop_infeasible()
    });

    // Adversarial queue: one huge job at the head, a stream of small
    // short jobs behind it — the classic case where backfilling shines
    // and LJF starves the small jobs.
    compare("adversarial: wide head + narrow stream (1 node x 64 cores)", || {
        let mut jobs = vec![Job::with_estimate(0, 0, 48, 7_200, 7_200)];
        for i in 1..400u64 {
            jobs.push(Job::with_estimate(i, 5 + i * 3, 4, 300, 450));
        }
        Workload::new("adversarial", jobs, 1, 64)
    });
}
